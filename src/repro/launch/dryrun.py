import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any jax import — jax locks the
# device count on first init — which is why they precede the module
# docstring and the __future__ import lives here as a comment-free zone.
DOC = """Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract roofline terms.

This is how the distribution config is proven coherent without hardware:
``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` must succeed for the
16×16 single-pod mesh AND the 2×16×16 multi-pod mesh for every cell; the
compiled artifact yields memory_analysis (fits), cost_analysis (FLOPs/bytes)
and the HLO collective schedule (DESIGN.md §5, EXPERIMENTS.md §Dry-run).

Results cache incrementally under ``dryrun_results/`` — one JSON per cell —
so a crashed sweep resumes where it stopped.

Usage:
  python -m repro.launch.dryrun --arch glm4_9b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""
# (no `from __future__ import annotations`: the XLA_FLAGS lines must stay
# first, and PEP 604 unions are native on this Python)

import argparse
import json
import time
import traceback

import jax

from ..configs import ARCH_IDS, SHAPES, ShapeSpec, cells, get_config, shapes_for
from ..models.api import Model
from ..parallel.sharding import (
    batch_specs,
    cache_shardings,
    dp_axes,
    dp_size,
)
from ..train.optimizer import AdamWConfig
from ..train.step import abstract_state, make_train_step, state_shardings
from . import specs as S
from .mesh import make_production_mesh
from .roofline import Roofline, collective_stats, hbm_bytes_estimate, model_flops_for

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "dryrun_results")


def _ns(mesh, spec):
    from jax.sharding import NamedSharding

    return NamedSharding(mesh, spec)


def make_cell_cfg(arch: str, *, moe_impl: str | None = None,
                  attention_impl: str | None = None,
                  param_dtype: str | None = None):
    from dataclasses import replace

    cfg = get_config(arch)
    overrides = {}
    # MoE under GSPMD: the token-sort/ragged path does not partition — use
    # the dense-einsum formulation as the auto-sharding baseline (§Perf logs
    # the ragged/EP upgrade separately).
    if cfg.moe_experts:
        overrides["moe_impl"] = moe_impl or "dense"
    if attention_impl:
        overrides["attention_impl"] = attention_impl
    if param_dtype:
        overrides["param_dtype"] = param_dtype
    if overrides:
        cfg = replace(cfg, **overrides)
    return cfg


def cost_variant_cfg(cfg, k: int):
    """Depth-k unrolled variant for cost extraction (see module docstring)."""
    from dataclasses import replace

    period = len(cfg.pattern())
    overrides = dict(
        n_layers=k * period, scan_blocks=False, attention_unroll=True
    )
    if cfg.enc_layers:
        overrides["enc_layers"] = k
    return replace(cfg, **overrides)


def lower_cell(cfg, shape: ShapeSpec, mesh, *, accum: int = 1,
               zero_opt: bool = False):
    """Lower + compile one cell for ``cfg``. Returns (lowered, compiled)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..parallel import ep_moe

    ep_moe.set_mesh(mesh)
    model = Model(cfg)
    ins = S.input_specs(model, cfg, shape)

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        train_step = make_train_step(model, opt_cfg, accum=accum)
        state = abstract_state(model, opt_cfg)
        st_sh = state_shardings(state, cfg, mesh, zero_opt=zero_opt)
        b_spec = batch_specs(cfg, mesh, shape.global_batch,
                             has_embeds="embeds" in ins["batch"],
                             encdec=cfg.enc_layers > 0)
        b_sh = {k: _ns(mesh, b_spec[k]) for k in ins["batch"]}
        with mesh:
            lowered = jax.jit(
                train_step,
                in_shardings=(st_sh, b_sh),
                out_shardings=(st_sh, None),
                donate_argnums=(0,),
            ).lower(state, ins["batch"])
    elif shape.kind == "prefill":
        params = model.abstract_params()
        from ..parallel.sharding import param_shardings

        p_sh = param_shardings(params, cfg, mesh)
        b_spec = batch_specs(cfg, mesh, shape.global_batch,
                             has_embeds="embeds" in ins["batch"])
        b_sh = {k: _ns(mesh, b_spec[k]) for k in ins["batch"]}
        c_sh = cache_shardings(cfg, mesh, ins["cache"], shape.global_batch)

        def prefill_step(params, batch, cache):
            return model.prefill(params, batch, cache)

        with mesh:
            lowered = jax.jit(
                prefill_step,
                in_shardings=(p_sh, b_sh, c_sh),
                out_shardings=(None, c_sh),
                donate_argnums=(2,),
            ).lower(params, ins["batch"], ins["cache"])
    else:  # decode
        params = model.abstract_params()
        from ..parallel.sharding import param_shardings

        p_sh = param_shardings(params, cfg, mesh)
        c_sh = cache_shardings(cfg, mesh, ins["cache"], shape.global_batch)
        dp = dp_axes(mesh)
        tok_ok = shape.global_batch % dp_size(mesh) == 0
        t_sh = _ns(mesh, P(dp if tok_ok else None, None))

        def decode_step(params, tokens, cache):
            return model.decode(params, tokens, cache)

        with mesh:
            lowered = jax.jit(
                decode_step,
                in_shardings=(p_sh, t_sh, c_sh),
                out_shardings=(None, c_sh),
                donate_argnums=(2,),
            ).lower(params, ins["tokens"], ins["cache"])
    compiled = lowered.compile()
    return lowered, compiled


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:  # noqa: BLE001
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def _memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:  # noqa: BLE001
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    return out


RESULT_VERSION = 2  # bump to invalidate cached cell JSONs


def _extract(compiled, chips: int) -> dict:
    """flops / bytes / collective stats of one compiled executable."""
    hlo = compiled.as_text()
    stats = collective_stats(hlo)
    cost = _cost_dict(compiled)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(hbm_bytes_estimate(hlo)),
        "bytes_upper": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes_by_kind": dict(stats.bytes_by_kind),
        "coll_count_by_kind": dict(stats.count_by_kind),
        "coll_bytes": float(stats.total_bytes),
    }


def _extrapolate(m1: dict, m2: dict, n_blocks: int) -> dict:
    """Depth-linear extrapolation: metric(L) = c0 + c1·L from L=1,2 blocks.

    XLA cost analysis counts while-loop bodies once, so the full scan model
    undercounts depth; the k=1 / k=2 UNROLLED variants give exact per-block
    costs and the depth-L total follows (every block is identical)."""

    def line(a, b):
        per = b - a
        return a + per * (n_blocks - 1)

    kinds = set(m1["coll_bytes_by_kind"]) | set(m2["coll_bytes_by_kind"])
    bbk = {
        k: max(line(m1["coll_bytes_by_kind"].get(k, 0),
                    m2["coll_bytes_by_kind"].get(k, 0)), 0)
        for k in kinds
    }
    cbk = {
        k: max(line(m1["coll_count_by_kind"].get(k, 0),
                    m2["coll_count_by_kind"].get(k, 0)), 0)
        for k in kinds
    }
    return {
        "flops": max(line(m1["flops"], m2["flops"]), 0.0),
        "bytes": max(line(m1["bytes"], m2["bytes"]), 0.0),
        "bytes_upper": max(line(m1["bytes_upper"], m2["bytes_upper"]), 0.0),
        "coll_bytes_by_kind": bbk,
        "coll_count_by_kind": cbk,
        "coll_bytes": float(sum(bbk.values())),
    }


def run_cell(arch: str, shape: ShapeSpec, mesh_kind: str, *, force: bool = False,
             moe_impl: str | None = None, attention_impl: str | None = None,
             param_dtype: str | None = None, accum: int = 1,
             zero_opt: bool = False, tag: str = "") -> dict:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(
        RESULTS_DIR, f"{mesh_kind}__{arch}__{shape.name}{suffix}.json"
    )
    cached = None
    if os.path.exists(path) and not force:
        with open(path) as f:
            cached = json.load(f)
        if cached.get("version") == RESULT_VERSION:
            return cached
        if cached.get("status") != "ok":
            cached = None  # re-run failed cells from scratch

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.size
    t0 = time.time()
    try:
        cfg = make_cell_cfg(arch, moe_impl=moe_impl,
                            attention_impl=attention_impl,
                            param_dtype=param_dtype)
        if cached is not None:
            # gate already passed under an older result version — reuse its
            # memory analysis and refresh only the (cheap) cost extraction
            mem = cached.get("memory_analysis", {})
            full_secs = cached.get("compile_seconds", 0.0)
        else:
            # 1. the gate: full-depth scan model must lower + compile
            _, compiled = lower_cell(cfg, shape, mesh, accum=accum,
                                     zero_opt=zero_opt)
            mem = _memory_dict(compiled)
            full_secs = time.time() - t0

        # 2. cost extraction: k=1 / k=2 unrolled variants, extrapolated
        t1 = time.time()
        # cost variants run accum=1: gradient accumulation adds a scan that
        # XLA cost analysis counts once; total per-optimizer-step FLOPs are
        # accum-invariant, so accum only affects the gate's memory analysis.
        m = []
        for k in (1, 2):
            _, c_k = lower_cell(cost_variant_cfg(cfg, k), shape, mesh,
                                accum=1, zero_opt=zero_opt)
            m.append(_extract(c_k, chips))
        cost = _extrapolate(m[0], m[1], cfg.n_blocks)
        cost_secs = time.time() - t1

        roof = Roofline.build(
            flops=cost["flops"],
            bytes_=cost["bytes"],
            coll_bytes=cost["coll_bytes"],
            chips=chips,
            model_flops=model_flops_for(cfg, shape),
            bytes_upper=cost["bytes_upper"],
        )
        result = {
            "version": RESULT_VERSION,
            "arch": arch,
            "shape": shape.name,
            "mesh": mesh_kind,
            "status": "ok",
            "compile_seconds": full_secs,
            "cost_extraction_seconds": cost_secs,
            "cost": cost,
            "memory_analysis": mem,
            "collectives": {
                "bytes_by_kind": cost["coll_bytes_by_kind"],
                "count_by_kind": cost["coll_count_by_kind"],
            },
            "roofline": roof.to_dict(),
            "overrides": {"moe_impl": moe_impl,
                          "attention_impl": attention_impl,
                          "param_dtype": param_dtype, "accum": accum,
                          "zero_opt": zero_opt},
        }
    except Exception as e:  # noqa: BLE001 — cell failures are data
        result = {
            "version": RESULT_VERSION,
            "arch": arch,
            "shape": shape.name,
            "mesh": mesh_kind,
            "status": "error",
            "compile_seconds": time.time() - t0,
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
    with open(path + ".tmp", "w") as f:
        json.dump(result, f, indent=1)
    os.replace(path + ".tmp", path)
    return result


def print_result(r: dict) -> None:
    if r["status"] != "ok":
        print(f"[FAIL] {r['mesh']:6s} {r['arch']:22s} {r['shape']:12s} "
              f"{r['error'][:120]}")
        return
    roof = r["roofline"]
    mem = r.get("memory_analysis", {})
    print(
        f"[ ok ] {r['mesh']:6s} {r['arch']:22s} {r['shape']:12s} "
        f"compute={roof['compute_s']:9.3e}s memory={roof['memory_s']:9.3e}s "
        f"coll={roof['collective_s']:9.3e}s dom={roof['dominant']:10s} "
        f"useful={roof['useful_ratio']:6.3f} "
        f"args={mem.get('argument_size_in_bytes', 0)/1e9:7.2f}GB "
        f"temp={mem.get('temp_size_in_bytes', 0)/1e9:7.2f}GB "
        f"({r['compile_seconds']:.0f}s compile)"
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--moe-impl", choices=["dense", "ragged", "gathered", "ep"],
                    default=None)
    ap.add_argument("--attention-impl",
                    choices=["blocked", "dense", "pallas"], default=None)
    ap.add_argument("--param-dtype", choices=["float32", "bfloat16"],
                    default=None)
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches (train cells)")
    ap.add_argument("--zero-opt", action="store_true",
                    help="ZeRO-1: shard optimizer state over the data axis")
    ap.add_argument("--tag", default="", help="result-file suffix for variants")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        todo = [(a, s) for a, s in cells()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, SHAPES[args.shape])]
        valid = {s.name for s in shapes_for(args.arch)}
        if args.shape not in valid:
            raise SystemExit(
                f"{args.arch} skips {args.shape} (sub-quadratic gate)"
            )

    failures = 0
    for mesh_kind in meshes:
        for arch, shape in todo:
            r = run_cell(arch, shape, mesh_kind, force=args.force,
                         moe_impl=args.moe_impl,
                         attention_impl=args.attention_impl,
                         param_dtype=args.param_dtype, accum=args.accum,
                         zero_opt=args.zero_opt, tag=args.tag)
            print_result(r)
            failures += r["status"] != "ok"
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
