"""Roofline term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (DESIGN.md §7):

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

``compiled.cost_analysis()`` reports the *per-device* SPMD program, so
per-device values divided by per-chip rates equal the global formula.
Collective bytes are not in cost_analysis: we parse the HLO text and sum
operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (async -start forms counted once).

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g. "  %x = f32[8,128]{1,0} all-reduce(%y), ..."
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES) + r")(?:-start)?\("
)
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
# definition lines: "  %name = <shape-or-tuple> opcode(...)" / "ROOT %name = ..."
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([^\s=]+)\s*=\s*((?:\([^)]*\)|\S+))\s")
_OPERAND_RE = re.compile(r"%([^\s,()]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _token_bytes(token: str) -> int:
    """Total bytes of all shapes in a shape/tuple token."""
    return sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(token))


@dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, int] = field(default_factory=dict)
    count_by_kind: dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


# Ops that genuinely stream HBM on TPU (elementwise chains fuse into their
# producers/consumers; XLA-CPU's "bytes accessed" counts every op and is kept
# as the upper bound).  Collectives are accounted separately.
_HBM_OPS = (
    "dot", "convolution", "gather", "scatter", "dynamic-slice",
    "dynamic-update-slice", "reduce", "reduce-window", "sort",
    "concatenate", "pad", "copy", "cholesky", "triangular-solve",
)
_HBM_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+(" + "|".join(_HBM_OPS) + r")\("
)


def hbm_bytes_estimate(hlo_text: str) -> int:
    """TPU-fusion-approximate HBM traffic: Σ operand+result bytes over
    data-moving ops only.  A lower-variance estimate than XLA-CPU
    bytes-accessed (which counts unfused elementwise I/O); still an
    approximation — see EXPERIMENTS.md §Roofline methodology."""
    table: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            table[m.group(1)] = _token_bytes(m.group(2))
    total = 0
    for line in hlo_text.splitlines():
        m = _HBM_RE.search(line)
        if not m:
            continue
        dm = _DEF_RE.match(line)
        result = _token_bytes(dm.group(2)) if dm else 0
        args = line[m.end():]
        depth, end = 1, len(args)
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = sum(
            table.get(name, 0) for name in _OPERAND_RE.findall(args[:end])
        )
        total += result + operands
    return total


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Per-device collective *operand* bytes, by collective kind.

    HLO text prints operands as bare ``%name`` references, so a first pass
    builds a name → shape-bytes symbol table from definition lines; the
    second pass resolves each collective's operands against it (falling back
    to the result shape when an operand is unresolvable, e.g. inlined
    constants)."""
    table: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            table[m.group(1)] = _token_bytes(m.group(2))

    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "-done(" in line:      # async pair: count the -start only
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        # operand list: balanced-paren slice after the opcode
        args = line[m.end():]
        depth = 1
        end = len(args)
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_text = args[:end]
        nbytes = sum(
            table.get(name, 0) for name in _OPERAND_RE.findall(operand_text)
        )
        if nbytes == 0:
            # fall back: inline shapes in the operand text, else result shape
            nbytes = _token_bytes(operand_text)
        if nbytes == 0:
            dm = _DEF_RE.match(line)
            nbytes = _token_bytes(dm.group(2)) if dm else 0
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float               # TPU-fusion-approx HBM traffic
    bytes_upper_bound_per_device: float   # raw XLA-CPU bytes accessed
    collective_bytes_per_device: float
    chips: int
    compute_s: float = 0.0
    memory_s: float = 0.0
    memory_upper_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    model_flops: float = 0.0
    useful_ratio: float = 0.0

    @staticmethod
    def build(flops: float, bytes_: float, coll_bytes: float, chips: int,
              model_flops: float, bytes_upper: float | None = None) -> "Roofline":
        r = Roofline(
            flops_per_device=flops,
            bytes_per_device=bytes_,
            bytes_upper_bound_per_device=(
                bytes_upper if bytes_upper is not None else bytes_
            ),
            collective_bytes_per_device=coll_bytes,
            chips=chips,
            model_flops=model_flops,
        )
        r.compute_s = flops / PEAK_FLOPS
        r.memory_s = bytes_ / HBM_BW
        r.memory_upper_s = r.bytes_upper_bound_per_device / HBM_BW
        r.collective_s = coll_bytes / LINK_BW
        terms = {
            "compute": r.compute_s,
            "memory": r.memory_s,
            "collective": r.collective_s,
        }
        r.dominant = max(terms, key=terms.get)
        global_flops = flops * chips
        r.useful_ratio = model_flops / global_flops if global_flops else 0.0
        return r

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """compute_term / max(all terms): 1.0 = the step is compute-bound at
        peak; lower = the dominant non-compute term caps MFU at this value."""
        b = self.bound_s
        return self.compute_s / b if b else 0.0

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "bytes_upper_bound_per_device": self.bytes_upper_bound_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "memory_upper_s": self.memory_upper_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(cfg, shape, active_only_for_moe: bool = True) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (prefill) / 2·N·B (decode),
    N = active params for MoE."""
    n = cfg.param_count(active_only=active_only_for_moe and cfg.moe_experts > 0)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
