"""input_specs(): ShapeDtypeStruct stand-ins for every (arch × shape) cell.

Weak-type-correct, shardable, zero allocation — what the dry-run lowers
against.  Shapes follow DESIGN.md §4: VLM cells split seq into 1024 patch
embeddings + text; enc-dec cells use T_enc = seq_len/4 frame embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs import ShapeSpec
from ..models.config import ModelConfig

F32 = jnp.float32
I32 = jnp.int32


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.enc_layers:                       # enc-dec: frames + decoder tokens
        t_enc = max(S // 4, 1)
        return {
            "tokens": sds((B, S), I32),
            "labels": sds((B, S), I32),
            "enc_embeds": sds((B, t_enc, cfg.d_model), F32),
        }
    if cfg.frontend_tokens:                  # VLM: patches + text
        text = S - cfg.frontend_tokens
        assert text > 0, f"{cfg.name}: seq {S} too short for frontend"
        return {
            "tokens": sds((B, text), I32),
            "labels": sds((B, text), I32),
            "embeds": sds((B, cfg.frontend_tokens, cfg.d_model), F32),
        }
    return {"tokens": sds((B, S), I32), "labels": sds((B, S), I32)}


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend_tokens:
        return {
            "tokens": sds((B, S - cfg.frontend_tokens), I32),
            "embeds": sds((B, cfg.frontend_tokens, cfg.d_model), F32),
        }
    return {"tokens": sds((B, S), I32)}


def decode_token_specs(cfg: ModelConfig, shape: ShapeSpec):
    return sds((shape.global_batch, 1), I32)


def abstract_cache(model, cfg: ModelConfig, shape: ShapeSpec):
    """Decode cache stand-in (eval_shape over init_cache — no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.enc_layers:
        t_enc = max(S // 4, 1)
        enc = sds((B, t_enc, cfg.d_model), F32)
        return jax.eval_shape(
            lambda p, e: model.init_cache(p, {"enc_embeds": e}, S),
            model.abstract_params(), enc,
        )
    from ..models import lm

    return jax.eval_shape(lambda: lm.init_cache(cfg, B, S))


def input_specs(model, cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """All abstract inputs for the cell's step function."""
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {
            "batch": prefill_batch_specs(cfg, shape),
            "cache": abstract_cache(model, cfg, shape),
        }
    if shape.kind == "decode":
        return {
            "tokens": decode_token_specs(cfg, shape),
            "cache": abstract_cache(model, cfg, shape),
        }
    raise ValueError(shape.kind)
