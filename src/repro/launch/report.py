"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun_results/.

    PYTHONPATH=src python -m repro.launch.report            # markdown tables
    PYTHONPATH=src python -m repro.launch.report --variants # incl. tag variants
"""
from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "dryrun_results")


def load(variants: bool = False) -> list[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        name = os.path.basename(p)[:-5]
        parts = name.split("__")
        is_variant = len(parts) > 3
        if is_variant and not variants:
            continue
        with open(p) as f:
            r = json.load(f)
        r["_tag"] = parts[3] if is_variant else ""
        out.append(r)
    return out


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table(rows: list[dict]) -> str:
    lines = [
        "| mesh | arch | shape | status | args/dev | temp/dev | "
        "collective ops (per-device bytes) | compile |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] != "ok":
            lines.append(
                f"| {r['mesh']} | {r['arch']} | {r['shape']} | FAIL: "
                f"{r.get('error', '?')[:60]} | | | | |"
            )
            continue
        mem = r.get("memory_analysis", {})
        coll = r.get("collectives", {}).get("bytes_by_kind", {})
        coll_s = ", ".join(
            f"{k}={fmt_bytes(v)}" for k, v in sorted(coll.items()) if v
        ) or "none"
        tag = f" ({r['_tag']})" if r.get("_tag") else ""
        lines.append(
            f"| {r['mesh']} | {r['arch']}{tag} | {r['shape']} | ok | "
            f"{fmt_bytes(mem.get('argument_size_in_bytes', 0))} | "
            f"{fmt_bytes(mem.get('temp_size_in_bytes', 0))} | {coll_s} | "
            f"{r.get('compile_seconds', 0):.0f}s |"
        )
    return "\n".join(lines)


def roofline_table(rows: list[dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | "
        "dominant | roofline frac | MODEL/HLO flops | one-line bottleneck note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] != "ok" or r["mesh"] != mesh:
            continue
        roof = r["roofline"]
        note = bottleneck_note(r)
        tag = f" ({r['_tag']})" if r.get("_tag") else ""
        lines.append(
            f"| {r['arch']}{tag} | {r['shape']} | {roof['compute_s']:.3e} | "
            f"{roof['memory_s']:.3e} | {roof['collective_s']:.3e} | "
            f"{roof['dominant']} | {roof.get('roofline_fraction', 0):.3f} | "
            f"{roof['useful_ratio']:.3f} | {note} |"
        )
    return "\n".join(lines)


def bottleneck_note(r: dict) -> str:
    roof = r["roofline"]
    dom = roof["dominant"]
    coll = r.get("collectives", {}).get("bytes_by_kind", {})
    big_coll = max(coll.items(), key=lambda kv: kv[1])[0] if coll else "none"
    shape = r["shape"]
    if dom == "collective":
        return f"dominated by {big_coll}; re-shard to cut its payload"
    if dom == "memory":
        if "decode" in shape or "500k" in shape:
            return "weight/KV streaming bound; cast serve params to bf16, shard cache"
        if roof["useful_ratio"] < 0.3:
            return "non-useful compute streams bytes (dense-MoE/remat); fix impl first"
        return "activation+weight traffic; raise arithmetic intensity (fusion/remat policy)"
    return "compute-bound: already at the MXU roofline knee"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--variants", action="store_true")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    rows = load(variants=args.variants)
    print("### Dry-run matrix\n")
    print(dryrun_table(rows))
    print(f"\n### Roofline ({args.mesh}-pod)\n")
    print(roofline_table(rows, mesh=args.mesh))


if __name__ == "__main__":
    main()
