"""Data substrate: deterministic host-sharded synthetic pipeline."""
from .pipeline import DataConfig, HostDataLoader, Prefetcher

__all__ = ["DataConfig", "HostDataLoader", "Prefetcher"]
