"""Synthetic host-sharded data pipeline.

Deterministic (seeded per host × step — restart-safe: resuming at step k
reproduces the exact batch), with controllable *skew* and *locality* knobs
that exercise the BigRoots data-skew and locality root causes end-to-end:

- ``skew_host``/``skew_factor``: one host's shards carry ×factor bytes (its
  ``read_bytes`` telemetry feature inflates and its load time grows).
- ``remote_prob``: probability a shard must be fetched "remotely" (locality
  code 2 + simulated fetch latency), feeding Eq. 7.

A background :class:`Prefetcher` overlaps host-side generation with device
compute (double buffering), which is what makes ``data_load_time`` a real
stall signal rather than a constant.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    batch_per_host: int
    seed: int = 0
    # skew / locality simulation
    skew_host: int | None = None
    skew_factor: float = 1.0
    remote_prob: float = 0.0
    remote_delay_s: float = 0.0
    # frontend stubs
    embed_tokens: int = 0      # VLM patch count
    d_model: int = 0
    enc_frames: int = 0        # enc-dec encoder length


@dataclass
class BatchMeta:
    read_bytes: float
    locality: int
    load_time: float


class HostDataLoader:
    """One host's shard of the global batch."""

    def __init__(self, cfg: DataConfig, host_id: int, num_hosts: int) -> None:
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts

    def batch_at(self, step: int) -> tuple[dict, BatchMeta]:
        cfg = self.cfg
        t0 = time.perf_counter()
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, self.host_id, step])
        )
        tokens = rng.integers(
            0, cfg.vocab, (cfg.batch_per_host, cfg.seq_len), dtype=np.int32
        )
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = 0
        batch = {"tokens": tokens, "labels": labels}
        if cfg.embed_tokens:
            batch["embeds"] = rng.normal(
                0, 1, (cfg.batch_per_host, cfg.embed_tokens, cfg.d_model)
            ).astype(np.float32)
        if cfg.enc_frames:
            batch["enc_embeds"] = rng.normal(
                0, 1, (cfg.batch_per_host, cfg.enc_frames, cfg.d_model)
            ).astype(np.float32)

        nbytes = float(sum(v.nbytes for v in batch.values()))
        locality = 0
        if cfg.skew_host is not None and self.host_id == cfg.skew_host:
            # skewed shard: more bytes to parse (simulated by busy-waiting on
            # an extra generation round) — the read_bytes feature records it
            nbytes *= cfg.skew_factor
            _ = rng.integers(0, cfg.vocab,
                             (int(cfg.batch_per_host * (cfg.skew_factor - 1)),
                              cfg.seq_len), dtype=np.int32)
        if cfg.remote_prob > 0 and rng.random() < cfg.remote_prob:
            locality = 2
            if cfg.remote_delay_s:
                time.sleep(cfg.remote_delay_s)
        return batch, BatchMeta(
            read_bytes=nbytes, locality=locality,
            load_time=time.perf_counter() - t0,
        )

    def __iter__(self) -> Iterator[tuple[dict, BatchMeta]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread double buffering over a HostDataLoader."""

    def __init__(self, loader: HostDataLoader, depth: int = 2,
                 start_step: int = 0) -> None:
        self.loader = loader
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        step = self._step
        while not self._stop.is_set():
            item = self.loader.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put(item, timeout=0.2)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self, timeout: float = 60.0) -> tuple[dict, BatchMeta]:
        return self.q.get(timeout=timeout)

    def stop(self) -> None:
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)

    def __enter__(self) -> "Prefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
