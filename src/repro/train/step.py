"""Train-step builder: fwd+bwd+AdamW with optional gradient accumulation and
int8 gradient compression (error feedback), returning a pure function the
launcher jits with mesh shardings (in_shardings=state/batch specs,
donate_argnums=0).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..models.api import Model
from ..parallel.compress import ef_compress
from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update

TrainState = dict  # {"params": ..., "opt": AdamWState, ["ef": residual]}


def init_state(model: Model, key, opt_cfg: AdamWConfig,
               compress: bool = False) -> TrainState:
    params = model.init(key)
    state: TrainState = {"params": params, "opt": adamw_init(params)}
    if compress:
        state["ef"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
    return state


def abstract_state(model: Model, opt_cfg: AdamWConfig,
                   compress: bool = False) -> TrainState:
    return jax.eval_shape(
        lambda k: init_state(model, k, opt_cfg, compress), jax.random.key(0)
    )


def make_train_step(
    model: Model,
    opt_cfg: AdamWConfig,
    accum: int = 1,
    compress: bool = False,
) -> Callable[[TrainState, dict], tuple[TrainState, dict]]:
    """Returns train_step(state, batch) → (state, metrics).

    ``accum > 1`` splits the per-step batch into microbatches accumulated via
    ``lax.scan`` (activation memory ÷ accum at the cost of serialization).
    ``compress=True`` quantize-dequantizes gradients (int8 + error feedback)
    before the optimizer — the numerics of compressed DP training.
    """

    def grad_fn(params, batch):
        return jax.value_and_grad(model.loss, has_aux=True)(params, batch)

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        params = state["params"]
        if accum <= 1:
            (_, metrics), grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch,
            )

            def body(carry, mb):
                g_acc, m_acc = carry
                (_, m), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                m_acc = jax.tree.map(lambda a, b: a + b, m_acc, m)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = jax.eval_shape(lambda: grad_fn(params, jax.tree.map(
                lambda x: x[0], micro))[0][1])
            m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m0)
            (grads, metrics), _ = jax.lax.scan(body, (g0, m0), micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            metrics = jax.tree.map(lambda m: m / accum, metrics)

        new_state: TrainState = {}
        if compress:
            grads, new_state["ef"] = ef_compress(grads, state["ef"])

        new_params, new_opt, opt_metrics = adamw_update(
            grads, state["opt"], params, opt_cfg
        )
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        return new_state, {**metrics, **opt_metrics}

    return train_step


def state_shardings(abstract: TrainState, cfg, mesh, zero_opt: bool = False):
    """Shardings for the full train state.

    Default: optimizer m/v follow their parameters (sharded over the model
    axis only, replicated across data).  ``zero_opt=True`` additionally
    shards m/v over the data axis (ZeRO-1): each data-parallel rank owns a
    slice of the optimizer state — memory ÷ dp_size at the cost of
    gather/scatter around the update, which XLA inserts automatically.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..parallel.sharding import dp_axes, dp_size, param_shardings

    p_sh = param_shardings(abstract["params"], cfg, mesh)

    def zero_shard(shardings, tree):
        """Add the dp axes to the first unsharded, divisible dim of each leaf."""
        dp = dp_axes(mesh)
        n = dp_size(mesh)

        def one(s: NamedSharding, leaf):
            spec = list(s.spec) + [None] * (leaf.ndim - len(s.spec))
            for i, (ax, dim) in enumerate(zip(spec, leaf.shape)):
                if ax is None and dim % n == 0 and dim > 0:
                    spec[i] = dp
                    return NamedSharding(mesh, P(*spec))
            return s

        return jax.tree.map(one, shardings, tree)

    m_sh = param_shardings(abstract["opt"].m, cfg, mesh)
    v_sh = param_shardings(abstract["opt"].v, cfg, mesh)
    if zero_opt:
        m_sh = zero_shard(m_sh, abstract["opt"].m)
        v_sh = zero_shard(v_sh, abstract["opt"].v)
    out: TrainState = {
        "params": p_sh,
        "opt": AdamWState(m=m_sh, v=v_sh, step=NamedSharding(mesh, P())),
    }
    if "ef" in abstract:
        out["ef"] = param_shardings(abstract["ef"], cfg, mesh)
    return out
