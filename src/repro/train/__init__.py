"""Training substrate: AdamW, schedules, train-step builder."""
from .optimizer import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    make_schedule,
)
from .step import abstract_state, init_state, make_train_step, state_shardings

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "abstract_state",
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "global_norm",
    "init_state",
    "make_schedule",
    "make_train_step",
    "state_shardings",
]
