"""AdamW + gradient clipping + LR schedules, pure JAX.

Optimizer state is a pytree shaped like the parameters (m, v) and therefore
shards with the same PartitionSpecs (ZeRO-style: every state shard lives
with its parameter shard; no replication of optimizer memory).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array       # [] int32


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"       # "cosine" | "linear" | "constant"
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def make_schedule(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def sched(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        frac = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0, 1.0,
        )
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
                1 + jnp.cos(jnp.pi * frac)
            )
        elif cfg.schedule == "linear":
            decay = 1.0 - (1 - cfg.min_lr_ratio) * frac
        else:
            decay = jnp.float32(1.0)
        return cfg.lr * warm * decay

    return sched


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


# Parameters exempt from weight decay (norms, biases, 1-d vectors).
def _decay_mask(path, leaf) -> bool:
    name = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
    return leaf.ndim >= 2 and "norm" not in name and not name.startswith("b")


def adamw_update(
    grads: Any, state: AdamWState, params: Any, cfg: AdamWConfig
) -> tuple[Any, AdamWState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = make_schedule(cfg)(step)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    decay_tree = jax.tree_util.tree_map_with_path(_decay_mask, params)

    def upd(p, g, m, v, decay):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if_decay = cfg.weight_decay if decay else 0.0
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (delta + if_decay * p32)
        return p_new.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v, decay_tree)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda o: isinstance(o, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda o: isinstance(o, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda o: isinstance(o, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(m=new_m, v=new_v, step=step), metrics
