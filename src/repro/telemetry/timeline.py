"""Resource timelines: per-(node, metric) time series with window queries.

The store behind Eq. 1-3 (window-averaged utilization) and Eq. 6 (edge
detection needs the mean utilization just before a task starts and just after
it ends).  Series are numpy-backed with prefix sums over capacity-doubled
buffers: appends are amortized O(1), the prefix sum extends incrementally for
in-order samples (one stable argsort only when out-of-order merges actually
happened), window means are two ``searchsorted`` calls plus a prefix-sum
difference, and the batched :meth:`window_means` resolves all edge queries of
a whole stage in one call — a multi-hour trace with thousands of nodes stays
fast.  A single lock makes interleaved writer/reader threads safe (the live
drivers sample from a background ``SystemSampler`` thread while the step loop
queries).
"""
from __future__ import annotations

import json
import threading
from typing import Iterable, Sequence

import numpy as np


class _Series:
    """One (node, metric) series in growable buffers + incremental prefix sum.

    ``_ts/_vals`` hold ``n`` valid samples; ``_csum[:n+1]`` is the prefix sum
    of ``_vals`` valid up to ``_csum_n`` samples.  Callers must hold the
    owning timeline's lock for every method and for reads of the views.
    """

    __slots__ = ("_ts", "_vals", "_csum", "n", "_csum_n", "_sorted",
                 "sort_gen")

    _INITIAL = 64

    def __init__(self) -> None:
        cap = self._INITIAL
        self._ts = np.empty(cap, dtype=np.float64)
        self._vals = np.empty(cap, dtype=np.float64)
        self._csum = np.zeros(cap + 1, dtype=np.float64)
        self.n = 0
        self._csum_n = 0
        self._sorted = True
        # Bumped whenever seal() re-sorts: cursors key their position hints
        # on it (a re-sort invalidates any remembered index).
        self.sort_gen = 0

    def _reserve(self, extra: int) -> None:
        need = self.n + extra
        cap = self._ts.shape[0]
        if need <= cap:
            return
        while cap < need:
            cap *= 2
        for name in ("_ts", "_vals"):
            old = getattr(self, name)
            new = np.empty(cap, dtype=np.float64)
            new[: self.n] = old[: self.n]
            setattr(self, name, new)
        csum = np.zeros(cap + 1, dtype=np.float64)
        csum[: self._csum_n + 1] = self._csum[: self._csum_n + 1]
        self._csum = csum

    def append(self, t: float, v: float) -> None:
        self._reserve(1)
        if self._sorted and self.n and t < self._ts[self.n - 1]:
            self._sorted = False
        self._ts[self.n] = t
        self._vals[self.n] = v
        self.n += 1

    def extend(self, ts: np.ndarray, vals: np.ndarray) -> None:
        m = ts.shape[0]
        if m == 0:
            return
        self._reserve(m)
        if self._sorted and (
            (self.n and ts[0] < self._ts[self.n - 1])
            or (m > 1 and np.any(np.diff(ts) < 0))
        ):
            self._sorted = False
        self._ts[self.n : self.n + m] = ts
        self._vals[self.n : self.n + m] = vals
        self.n += m

    def seal(self) -> "_Series":
        """Make ``ts``/``csum`` views consistent: sort if out-of-order merges
        happened (rare), then extend the prefix sum over new samples only."""
        n = self.n
        if not self._sorted:
            order = np.argsort(self._ts[:n], kind="stable")
            self._ts[:n] = self._ts[:n][order]
            self._vals[:n] = self._vals[:n][order]
            self._sorted = True
            self._csum_n = 0
            self.sort_gen += 1
        if self._csum_n < n:
            m = self._csum_n
            self._csum[m + 1 : n + 1] = self._csum[m] + np.cumsum(
                self._vals[m:n]
            )
            self._csum_n = n
        return self

    @property
    def ts(self) -> np.ndarray:
        return self._ts[: self.n]

    @property
    def vals(self) -> np.ndarray:
        return self._vals[: self.n]

    @property
    def csum(self) -> np.ndarray:
        return self._csum[: self.n + 1]


class ResourceTimeline:
    """Append-mostly store of (t, value) samples keyed by (node, metric).

    Thread-safe: writers (e.g. the ``SystemSampler`` background thread) and
    readers (per-step ``window_mean`` in the telemetry loop) serialize on one
    internal lock.
    """

    def __init__(self) -> None:
        self._series: dict[tuple[str, str], _Series] = {}
        self._lock = threading.Lock()

    def _get(self, node: str, metric: str) -> _Series:
        key = (node, metric)
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = _Series()
        return s

    # -- writing ---------------------------------------------------------------
    def record(self, node: str, metric: str, t: float, value: float) -> None:
        with self._lock:
            self._get(node, metric).append(float(t), float(value))

    def record_many(self, node: str, metric: str,
                    samples: Iterable[tuple[float, float]]) -> None:
        pairs = list(samples)
        if not pairs:
            return
        arr = np.asarray(pairs, dtype=np.float64)
        with self._lock:
            self._get(node, metric).extend(arr[:, 0], arr[:, 1])

    # -- queries ------------------------------------------------------------
    def window_mean(self, node: str, metric: str, t0: float, t1: float) -> float | None:
        """Mean of samples with t0 <= t <= t1; None if no samples in window."""
        with self._lock:
            s = self._series.get((node, metric))
            if s is None or s.n == 0:
                return None
            s.seal()
            lo = int(np.searchsorted(s.ts, t0, side="left"))
            hi = int(np.searchsorted(s.ts, t1, side="right"))
            if hi <= lo:
                return None
            return float((s.csum[hi] - s.csum[lo]) / (hi - lo))

    def window_means(
        self,
        nodes: Sequence[str],
        metrics: Sequence[str],
        t0s: np.ndarray,
        t1s: np.ndarray,
    ) -> np.ndarray:
        """Batched :meth:`window_mean`: element i is the mean of
        (nodes[i], metrics[i]) over [t0s[i], t1s[i]], NaN where no samples
        cover the window (or the series doesn't exist).

        Queries are grouped per series so each series is sealed once and all
        its windows resolve in two vectorized ``searchsorted`` calls — this
        is how all Eq. 6 edge queries of a stage collapse into one call.
        """
        t0s = np.asarray(t0s, dtype=np.float64)
        t1s = np.asarray(t1s, dtype=np.float64)
        out = np.full(len(nodes), np.nan, dtype=np.float64)
        groups: dict[tuple[str, str], list[int]] = {}
        for idx, key in enumerate(zip(nodes, metrics)):
            groups.setdefault(key, []).append(idx)
        with self._lock:
            for key, idx_list in groups.items():
                s = self._series.get(key)
                if s is None or s.n == 0:
                    continue
                s.seal()
                idx = np.asarray(idx_list, dtype=np.int64)
                lo = np.searchsorted(s.ts, t0s[idx], side="left")
                hi = np.searchsorted(s.ts, t1s[idx], side="right")
                ok = hi > lo
                if np.any(ok):
                    out[idx[ok]] = (
                        s.csum[hi[ok]] - s.csum[lo[ok]]
                    ) / (hi[ok] - lo[ok])
        return out

    def cursor(self) -> "TimelineCursor":
        """Incremental query cursor for monotonically advancing windows
        (the in-loop Eq. 6 edge queries of a streaming analyzer)."""
        return TimelineCursor(self)

    def series(self, node: str, metric: str) -> tuple[list[float], list[float]]:
        with self._lock:
            s = self._series.get((node, metric))
            if s is None:
                return [], []
            s.seal()
            return s.ts.tolist(), s.vals.tolist()

    def nodes(self) -> list[str]:
        with self._lock:
            return sorted({n for (n, _m) in self._series})

    def metrics(self, node: str) -> list[str]:
        with self._lock:
            return sorted({m for (n, m) in self._series if n == node})

    def __len__(self) -> int:
        with self._lock:
            return sum(s.n for s in self._series.values())

    # -- persistence -------------------------------------------------------
    def dump_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            with self._lock:
                rows = [
                    (node, metric, s.seal().ts.tolist(), s.vals.tolist())
                    for (node, metric), s in self._series.items()
                ]
            for node, metric, ts, vals in rows:
                f.write(json.dumps({"node": node, "metric": metric,
                                    "ts": ts, "vals": vals}) + "\n")

    @staticmethod
    def load_jsonl(path: str) -> "ResourceTimeline":
        tl = ResourceTimeline()
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                obj = json.loads(line)
                tl.record_many(obj["node"], obj["metric"],
                               zip(obj["ts"], obj["vals"]))
        return tl


class TimelineCursor:
    """Incremental :meth:`ResourceTimeline.window_means` for in-loop use.

    A streaming analyzer issues edge-detection windows whose bounds advance
    monotonically with wall time (each step queries slightly later windows
    than the last).  The cursor remembers, per series, the smallest index
    the previous call resolved to and restricts the next ``searchsorted``
    to the suffix from there — the binary search runs over the recent tail
    instead of the whole multi-hour series.  Correctness guards:

    - the hint is only used when every queried ``t0`` lies strictly after
      the sample just before the hint (otherwise: full search — answers are
      *always* exact, the cursor is only a lower-bound accelerator);
    - a series re-sort (out-of-order bulk merge) bumps ``sort_gen``, which
      invalidates the hint;
    - the effective hint is the minimum over the *last two* calls: the
      analyzer alternates head windows (``start - edge_width``) and tail
      windows (``end``) per step, and the head of step k+1 starts before
      the tail of step k — a single-call hint would trip the exactness
      guard on every other call and degenerate to full searches.

    Same query contract as :meth:`ResourceTimeline.window_means` /
    :meth:`ResourceTimeline.window_mean`, so it satisfies the analyzer's
    ``TimelineStore`` protocol and slots in transparently.
    """

    def __init__(self, timeline: ResourceTimeline) -> None:
        self.timeline = timeline
        # key -> (sort_gen, prev-call min lo, last-call min lo)
        self._hints: dict[tuple[str, str], tuple[int, int, int]] = {}

    def window_means(
        self,
        nodes: Sequence[str],
        metrics: Sequence[str],
        t0s: np.ndarray,
        t1s: np.ndarray,
    ) -> np.ndarray:
        tl = self.timeline
        t0s = np.asarray(t0s, dtype=np.float64)
        t1s = np.asarray(t1s, dtype=np.float64)
        out = np.full(len(nodes), np.nan, dtype=np.float64)
        groups: dict[tuple[str, str], list[int]] = {}
        for i, key in enumerate(zip(nodes, metrics)):
            groups.setdefault(key, []).append(i)
        with tl._lock:
            for key, idx_list in groups.items():
                s = tl._series.get(key)
                if s is None or s.n == 0:
                    continue
                s.seal()
                idx = np.asarray(idx_list, dtype=np.int64)
                gen, prev_lo, last_lo = self._hints.get(key, (-1, 0, 0))
                base = min(prev_lo, last_lo) if gen == s.sort_gen else 0
                if base > s.n or (
                    base > 0 and s._ts[base - 1] >= float(t0s[idx].min())
                ):
                    base = 0
                tail = s.ts[base:]
                lo = base + np.searchsorted(tail, t0s[idx], side="left")
                hi = base + np.searchsorted(tail, t1s[idx], side="right")
                ok = hi > lo
                if np.any(ok):
                    out[idx[ok]] = (
                        s.csum[hi[ok]] - s.csum[lo[ok]]
                    ) / (hi[ok] - lo[ok])
                carry = last_lo if gen == s.sort_gen else 0
                self._hints[key] = (s.sort_gen, carry, int(lo.min()))
        return out

    def window_mean(self, node: str, metric: str, t0: float, t1: float) -> float | None:
        got = self.window_means([node], [metric], np.array([t0]), np.array([t1]))
        return None if np.isnan(got[0]) else float(got[0])
