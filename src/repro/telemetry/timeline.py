"""Resource timelines: per-(node, metric) time series with window queries.

The store behind Eq. 1-3 (window-averaged utilization) and Eq. 6 (edge
detection needs the mean utilization just before a task starts and just after
it ends).  Samples are appended in time order by the 1 Hz sampler; queries
use binary search, so a multi-hour trace with thousands of nodes stays fast.
"""
from __future__ import annotations

import bisect
import json
from collections import defaultdict
from typing import Iterable


class ResourceTimeline:
    """Append-mostly store of (t, value) samples keyed by (node, metric)."""

    def __init__(self) -> None:
        self._ts: dict[tuple[str, str], list[float]] = defaultdict(list)
        self._vals: dict[tuple[str, str], list[float]] = defaultdict(list)

    # -- writing ---------------------------------------------------------------
    def record(self, node: str, metric: str, t: float, value: float) -> None:
        key = (node, metric)
        ts = self._ts[key]
        if ts and t < ts[-1]:
            # Out-of-order insert (merged traces): keep sorted.
            i = bisect.bisect_left(ts, t)
            ts.insert(i, t)
            self._vals[key].insert(i, value)
        else:
            ts.append(t)
            self._vals[key].append(value)

    def record_many(self, node: str, metric: str,
                    samples: Iterable[tuple[float, float]]) -> None:
        for t, v in samples:
            self.record(node, metric, t, v)

    # -- queries ------------------------------------------------------------
    def window_mean(self, node: str, metric: str, t0: float, t1: float) -> float | None:
        """Mean of samples with t0 <= t <= t1; None if no samples in window."""
        key = (node, metric)
        ts = self._ts.get(key)
        if not ts:
            return None
        lo = bisect.bisect_left(ts, t0)
        hi = bisect.bisect_right(ts, t1)
        if hi <= lo:
            return None
        vals = self._vals[key]
        return sum(vals[lo:hi]) / (hi - lo)

    def series(self, node: str, metric: str) -> tuple[list[float], list[float]]:
        key = (node, metric)
        return list(self._ts.get(key, [])), list(self._vals.get(key, []))

    def nodes(self) -> list[str]:
        return sorted({n for (n, _m) in self._ts})

    def metrics(self, node: str) -> list[str]:
        return sorted({m for (n, m) in self._ts if n == node})

    def __len__(self) -> int:
        return sum(len(v) for v in self._ts.values())

    # -- persistence -------------------------------------------------------
    def dump_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for (node, metric), ts in self._ts.items():
                vals = self._vals[(node, metric)]
                f.write(json.dumps({"node": node, "metric": metric,
                                    "ts": ts, "vals": vals}) + "\n")

    @staticmethod
    def load_jsonl(path: str) -> "ResourceTimeline":
        tl = ResourceTimeline()
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                obj = json.loads(line)
                tl._ts[(obj["node"], obj["metric"])] = list(map(float, obj["ts"]))
                tl._vals[(obj["node"], obj["metric"])] = list(map(float, obj["vals"]))
        return tl
