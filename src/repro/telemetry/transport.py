"""Cross-process :class:`~repro.telemetry.events.StepDelta` transport.

PR 4 built the fleet-merge substrate but left the transport in-process:
``FleetAggregator.ingest`` only ever saw bytes handed to it by the same
Python process.  This module is the real boundary crossing — per-host
producers on one side, the launcher-side aggregator on the other — with
loss, reordering, and reconnection handled explicitly:

- :class:`DeltaServer` / :class:`DeltaClient`: a length-prefixed framed
  channel over TCP or a Unix-domain socket.  The client keeps every sent
  delta in a bounded resend buffer until the server acknowledges its
  ``(boot, seq)``; a dropped connection reconnects with backoff and
  replays the unacked tail in order.  Delivery is therefore
  **at-least-once and per-host FIFO** — exactly the contract
  :class:`~repro.serve.FleetAggregator`'s per-incarnation ``(boot, seq)``
  watermark dedups safely (a replayed delta is dropped whole; a restarted
  host's new ``boot`` is accepted immediately).
- :class:`ShmRing`: a same-machine shared-memory SPSC ring fast path —
  one producer process pushes framed payloads, one consumer pops them,
  no syscalls per record and no serialization beyond the wire payload
  itself.  No acks: within one machine the ring is lossless while both
  ends are alive, and a full ring back-pressures the producer
  (``push`` returns False).

Framing (normative spec in ``docs/wire_format.md``): every socket frame is

    u32 LE body length | u8 frame type | body

with type ``DATA`` (1) carrying ``u64 boot | u64 seq | StepDelta payload``
and type ``ACK`` (2) carrying ``u64 boot | u64 seq``.  The ``(boot, seq)``
ride *outside* the (possibly compressed) delta payload so the server acks
without decoding and the client tracks resends without keeping decoded
objects alive.

The server acknowledges a DATA frame once it is enqueued in server-process
memory; ``drain_into`` hands queued payloads to the aggregator on the
driver thread (the aggregator is not thread-safe and never touched by
socket threads).  An ack therefore means "durable as long as the
aggregator process lives" — if the aggregator process dies, its merged
windows die with the queue, so no stronger durability would be observable.
"""
from __future__ import annotations

import os
import queue
import socket
import struct
import threading
import time
import zlib
from collections import OrderedDict
from dataclasses import dataclass

from .events import StepDelta, WireFormatError

FRAME_DATA = 1
FRAME_ACK = 2

_FRAME_HEAD = struct.Struct("<IB")
_BOOT_SEQ = struct.Struct("<QQ")

#: Refuse frames larger than this (a corrupt length prefix must not make
#: the receiver allocate gigabytes).
MAX_FRAME_BYTES = 64 << 20


class TransportError(RuntimeError):
    """A transport-layer failure (bad frame, oversized frame, closed peer)."""


@dataclass(frozen=True)
class Endpoint:
    """A typed transport endpoint: ``tcp`` (host + port), ``unix`` (socket
    path), or ``shm`` (shared-memory segment name).

    This is the one wiring surface every transport role shares — host,
    aggregator, and root all express "where do I listen / whom do I dial"
    as an Endpoint instead of the historical stringly-typed address
    tuples.  :meth:`parse` accepts every form the old ``parse_address``
    did (``("host", port)`` tuples, ``"host:port"``, ``"unix:/path"``, a
    bare path containing ``/``) plus the explicit ``tcp:host:port`` and
    ``shm:name`` prefixes, and an Endpoint itself (idempotent), so string
    forms keep working everywhere they ever did.

    :meth:`listen` and :meth:`connect` are the factories the roles use
    uniformly: ``listen`` binds a :class:`DeltaServer` (tcp/unix) or
    creates a :class:`ShmRing` (shm); ``connect`` dials a
    :class:`DeltaClient` (tcp/unix) or attaches a :class:`RingSender`
    (shm).  ``str(endpoint)`` is the canonical advertisable form and
    round-trips through :meth:`parse`.
    """

    kind: str                  # "tcp" | "unix" | "shm"
    host: str = ""             # tcp only
    port: int = 0              # tcp only
    path: str = ""             # unix socket path or shm segment name

    _KINDS = ("tcp", "unix", "shm")

    def __post_init__(self) -> None:
        if self.kind not in self._KINDS:
            raise ValueError(f"unknown endpoint kind {self.kind!r}")

    @classmethod
    def parse(cls, value) -> "Endpoint":
        """Normalize any accepted address form into an Endpoint."""
        if isinstance(value, Endpoint):
            return value
        if isinstance(value, tuple) and len(value) == 2:
            host, port = value
            return cls("tcp", host=str(host), port=int(port))
        if isinstance(value, str) and value:
            if value.startswith("unix:"):
                return cls("unix", path=value[len("unix:"):])
            if value.startswith("shm:"):
                return cls("shm", path=value[len("shm:"):])
            if value.startswith("tcp:"):
                value = value[len("tcp:"):]
                if ":" not in value:
                    raise ValueError(f"tcp endpoint needs host:port, got {value!r}")
            if ":" in value and not value.startswith("/"):
                host, _, port = value.rpartition(":")
                return cls("tcp", host=host or "127.0.0.1", port=int(port))
            if "/" in value:
                return cls("unix", path=value)
        raise ValueError(f"unparseable transport address {value!r}")

    def __str__(self) -> str:
        if self.kind == "tcp":
            return f"{self.host}:{self.port}"
        return f"{self.kind}:{self.path}"

    # -- socket plumbing ----------------------------------------------------
    @property
    def family(self) -> int:
        if self.kind == "tcp":
            return socket.AF_INET
        if self.kind == "unix":
            return socket.AF_UNIX
        raise ValueError("shm endpoints have no socket family")

    @property
    def sockaddr(self):
        if self.kind == "tcp":
            return (self.host, self.port)
        if self.kind == "unix":
            return self.path
        raise ValueError("shm endpoints have no socket address")

    # -- role factories -----------------------------------------------------
    def listen(self, **kwargs):
        """Bind the listening side: a :class:`DeltaServer` for tcp/unix, a
        created :class:`ShmRing` for shm (kwargs pass through)."""
        if self.kind == "shm":
            return ShmRing.create(name=self.path or None, **kwargs)
        return DeltaServer(self, **kwargs)

    def connect(self, **kwargs):
        """Dial the producing side: a :class:`DeltaClient` for tcp/unix, a
        :class:`RingSender` over an attached :class:`ShmRing` for shm."""
        if self.kind == "shm":
            return RingSender(ShmRing.attach(self.path), **kwargs)
        return DeltaClient(self, **kwargs)


def parse_address(address) -> tuple[int, object]:
    """Normalize an address to ``(socket family, sockaddr)``.

    Back-compat shim over :meth:`Endpoint.parse`: ``("host", port)``
    tuples and ``"host:port"`` strings are TCP (``AF_INET``);
    ``"unix:/path"`` (or a bare path containing ``/``) is a Unix-domain
    socket (``AF_UNIX``).  ``shm:`` endpoints have no socket family and
    raise ``ValueError`` here — use :class:`Endpoint` directly.
    """
    ep = Endpoint.parse(address)
    return ep.family, ep.sockaddr


def _recv_exact(sock: socket.socket, count: int) -> bytes | None:
    """Read exactly ``count`` bytes, or None on clean EOF at a frame
    boundary; raises on mid-frame EOF."""
    chunks = []
    got = 0
    while got < count:
        chunk = sock.recv(min(count - got, 1 << 20))
        if not chunk:
            if got == 0:
                return None
            raise TransportError(
                f"peer closed mid-frame ({got}/{count} bytes)"
            )
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def _read_frame(sock: socket.socket) -> tuple[int, bytes] | None:
    head = _recv_exact(sock, _FRAME_HEAD.size)
    if head is None:
        return None
    length, ftype = _FRAME_HEAD.unpack(head)
    if length > MAX_FRAME_BYTES:
        raise TransportError(f"frame of {length} bytes exceeds MAX_FRAME_BYTES")
    body = _recv_exact(sock, length) if length else b""
    if body is None and length:
        raise TransportError("peer closed before frame body")
    return ftype, body or b""


def _send_frame(sock: socket.socket, ftype: int, body: bytes) -> None:
    sock.sendall(_FRAME_HEAD.pack(len(body), ftype) + body)


class DeltaServer:
    """Aggregator-side socket endpoint: accept host connections, queue
    their delta payloads, ack each ``(boot, seq)`` on enqueue.

    Socket work happens on background threads; the aggregator is only
    touched from whatever thread calls :meth:`drain_into` (one call per
    diagnosis tick is the intended cadence)::

        server = DeltaServer(("127.0.0.1", 0))     # port 0 = ephemeral
        addr = server.address                       # advertise to hosts
        ... each tick ...
        server.drain_into(aggregator)
        for cause in aggregator.step(): ...

    ``address`` accepts every form of :meth:`Endpoint.parse`.  A
    Unix-socket path is unlinked on :meth:`close`.

    Ack timing (``ack``): ``"enqueue"`` (default) acknowledges a DATA
    frame the moment it is queued in server-process memory — "durable as
    long as the aggregator process lives".  ``"drain"`` defers the ack
    until :meth:`drain_into` has *ingested* the payload, so an aggregator
    that journals on ingest upgrades the ack to "durable across my own
    restart" — the HA contract a tree aggregator gives its children
    (plain :meth:`drain` in this mode acks on pop, since the caller took
    ownership).  In drain mode acks are sent from the draining thread;
    the per-connection reader threads never write, so no send lock is
    needed in either mode.

    Fault injection (``fault``): an optional hook called once per
    received DATA frame with ``(boot, seq, payload)``, returning one of

    - ``"pass"`` — deliver normally (also the meaning of any unknown
      verdict, so a buggy hook degrades to a no-op);
    - ``"drop"`` — discard the frame *without acking* and sever the
      connection, modelling receiver-side loss: the client's resend
      contract replays the unacked tail on reconnect;
    - ``"dup"`` — enqueue the payload twice (one ack), modelling
      at-least-once duplication — the aggregator's ``(boot, seq)``
      watermark absorbs the copy;
    - ``"reorder"`` — hold the frame back and enqueue it *after* the
      next frame from the same connection, modelling a reordering
      channel.  Downstream needs
      :class:`~repro.serve.fleet.FleetAggregator` ``reorder_window > 0``
      to reconstruct the gap, otherwise the late frame is (by contract)
      dropped as a duplicate.

    Every non-pass verdict is counted in ``faults_injected``.  The hook
    exists for tests and the scenario engine
    (:mod:`repro.anomaly.scenario`); production servers leave it None.
    """

    def __init__(self, address, *, backlog: int = 16,
                 ack: str = "enqueue", fault=None) -> None:
        if ack not in ("enqueue", "drain"):
            raise ValueError(f"unknown ack mode {ack!r}")
        self.ack_mode = ack
        self.fault = fault
        self.faults_injected = 0
        self.endpoint = Endpoint.parse(address)
        self.family = self.endpoint.family
        self._sock = socket.socket(self.family, socket.SOCK_STREAM)
        if self.family == socket.AF_INET:
            self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind(self.endpoint.sockaddr)
        self._sock.listen(backlog)
        self.address = self._sock.getsockname()
        if self.endpoint.kind == "tcp":
            # Re-anchor on the *bound* port (port 0 = ephemeral).
            self.endpoint = Endpoint("tcp", host=self.address[0],
                                     port=self.address[1])
        # Items are (payload, ack) where ack is None (already acked at
        # enqueue) or a zero-arg callable sending the deferred ack.
        self._queue: queue.Queue[tuple[bytes, object]] = queue.Queue()
        self._closed = False
        self._conns: list[socket.socket] = []
        self._lock = threading.Lock()
        self.frames_received = 0
        self.bytes_received = 0
        self.connections_accepted = 0
        self.frame_errors = 0
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="DeltaServer.accept", daemon=True
        )
        self._accept_thread.start()

    # -- background threads ------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return  # closed
            with self._lock:
                if self._closed:
                    conn.close()
                    return
                self._conns.append(conn)
                self.connections_accepted += 1
            threading.Thread(
                target=self._conn_loop, args=(conn,),
                name="DeltaServer.conn", daemon=True,
            ).start()

    def _conn_loop(self, conn: socket.socket) -> None:
        # One reader thread per connection is the only writer of its acks,
        # so no send lock is needed here.
        held: list[tuple[int, int, bytes]] = []  # "reorder" fault holdback

        def enqueue(boot: int, seq: int, payload: bytes) -> None:
            if self.ack_mode == "enqueue":
                self._queue.put((payload, None))
                _send_frame(conn, FRAME_ACK, _BOOT_SEQ.pack(boot, seq))
            else:
                self._queue.put((payload, self._deferred_ack(conn, boot, seq)))
            self.frames_received += 1
            self.bytes_received += len(payload)

        try:
            while True:
                frame = _read_frame(conn)
                if frame is None:
                    return
                ftype, body = frame
                if ftype != FRAME_DATA or len(body) < _BOOT_SEQ.size:
                    self.frame_errors += 1
                    return  # protocol violation: drop the connection
                boot, seq = _BOOT_SEQ.unpack_from(body, 0)
                payload = body[_BOOT_SEQ.size:]
                verdict = (self.fault(boot, seq, payload)
                           if self.fault is not None else "pass")
                if verdict == "drop":
                    # Receiver-side loss: no enqueue, no ack — sever so
                    # the client replays the unacked tail on reconnect.
                    self.faults_injected += 1
                    return
                if verdict == "reorder":
                    self.faults_injected += 1
                    held.append((boot, seq, payload))
                    continue
                enqueue(boot, seq, payload)
                if verdict == "dup":
                    self.faults_injected += 1
                    self._queue.put((payload, None))
                while held:
                    enqueue(*held.pop(0))
        except (TransportError, OSError):
            self.frame_errors += 1
        finally:
            # A frame still held back when the connection dies is
            # enqueued anyway — holdback reorders, it must never lose.
            for boot, seq, payload in held:
                try:
                    enqueue(boot, seq, payload)
                except OSError:
                    self._queue.put((payload, None))
            with self._lock:
                if conn in self._conns:
                    self._conns.remove(conn)
            conn.close()

    # -- driver-thread surface ---------------------------------------------
    @staticmethod
    def _deferred_ack(conn: socket.socket, boot: int, seq: int):
        def send_ack() -> None:
            try:
                _send_frame(conn, FRAME_ACK, _BOOT_SEQ.pack(boot, seq))
            except OSError:
                pass  # dead connection: the client will resend on reconnect
        return send_ack

    @property
    def pending(self) -> int:
        return self._queue.qsize()

    def drain(self, max_payloads: int | None = None) -> list[bytes]:
        """Pop queued delta payloads (all of them by default).  In
        ``ack="drain"`` mode each popped payload is acked here — the
        caller took ownership; use :meth:`drain_into` to defer acks past
        ingest instead."""
        out: list[bytes] = []
        while max_payloads is None or len(out) < max_payloads:
            try:
                payload, ack = self._queue.get_nowait()
            except queue.Empty:
                break
            out.append(payload)
            if ack is not None:
                ack()
        return out

    def drain_into(self, aggregator, max_payloads: int | None = None) -> int:
        """Ingest every queued payload into ``aggregator`` (its
        ``(boot, seq)`` dedup makes replayed frames free).  A payload that
        fails wire validation is dropped and counted in ``frame_errors``
        rather than poisoning the tick (and still acked — it would be
        corrupt on every redelivery too).  In ``ack="drain"`` mode the ack
        goes out only after ``ingest`` returned, so an aggregator that
        journals inside ingest never acks a payload it could lose.
        Returns rows ingested."""
        rows = 0
        n = 0
        while max_payloads is None or n < max_payloads:
            try:
                payload, ack = self._queue.get_nowait()
            except queue.Empty:
                break
            n += 1
            try:
                rows += aggregator.ingest(payload)
            except WireFormatError:
                self.frame_errors += 1
            if ack is not None:
                ack()
        return rows

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            conns = list(self._conns)
        try:
            # Wake a thread blocked in accept(); close() alone does not on
            # every kernel, and a pinned accept keeps the port in LISTEN.
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()
        self._accept_thread.join(timeout=1.0)
        for conn in conns:
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            conn.close()
        if self.family == socket.AF_UNIX and isinstance(self.address, str):
            try:
                os.unlink(self.address)
            except OSError:
                pass

    def __enter__(self) -> "DeltaServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class DeltaClient:
    """Host-side socket endpoint with at-least-once resend.

    :meth:`send` serializes the delta (wire v2 by default), stamps its
    ``(boot, seq)`` on the frame, appends it to the unacked buffer, and
    transmits if connected.  A send on a dead connection buffers the frame
    and triggers a (rate-limited) reconnect attempt; on reconnect the
    whole unacked tail is replayed in order before new frames — the
    aggregator's per-incarnation seq watermark drops anything the server
    already saw.  ``flush()`` blocks until every buffered frame is acked
    (retrying connects) — call it before process exit so a crash-free run
    loses nothing.

    The buffer is bounded (``resend_cap`` frames): while the aggregator
    is unreachable beyond it, the *oldest* frames are shed and counted in
    ``resend_drops`` — live telemetry prefers losing the stale tail to
    growing without bound.  Socket sends are bounded too
    (``send_timeout``, via ``SO_SNDTIMEO`` so the ack reader's recv is
    untouched): an aggregator that stops draining fills the TCP window
    and the send fails over to the resend buffer instead of hanging the
    caller's step loop.

    ``clock`` (default ``time.monotonic``) is the timebase for reconnect
    rate-limiting and the ``flush`` deadline — inject a simulated clock
    (:mod:`repro.anomaly.scenario`, tests) to run resend timing at
    simulated time; the default keeps wall-clock behavior byte-identical.
    ``fault`` is an optional sender-side hook called once per first
    transmission with ``(boot, seq, payload)``: ``"drop"`` buffers the
    frame but severs the connection instead of sending (the frame goes
    out with the reconnect replay — sender-side loss), ``"dup"``
    transmits the frame twice; anything else passes.  Replayed frames are
    never faulted, so every injected loss converges.  Non-pass verdicts
    count in ``faults_injected``.
    """

    def __init__(
        self,
        address,
        *,
        wire_version: int | None = None,
        resend_cap: int = 1024,
        connect_timeout: float = 5.0,
        retry_interval: float = 0.2,
        send_timeout: float = 5.0,
        clock=time.monotonic,
        fault=None,
    ) -> None:
        self.endpoint = Endpoint.parse(address)
        self.family, self.sockaddr = self.endpoint.family, self.endpoint.sockaddr
        # None = StepDelta.to_bytes auto-select: v2, upgraded to v3 only
        # when the delta carries attributed causes.
        self.wire_version = None if wire_version is None else int(wire_version)
        self.resend_cap = int(resend_cap)
        self.connect_timeout = float(connect_timeout)
        self.retry_interval = float(retry_interval)
        self.send_timeout = float(send_timeout)
        self.clock = clock
        self.fault = fault
        self.faults_injected = 0
        self._sock: socket.socket | None = None
        self._reader: threading.Thread | None = None
        self._gen = 0  # bumps per (re)connect so stale readers exit
        self._lock = threading.Lock()
        self._acked = threading.Condition(self._lock)
        self._unacked: OrderedDict[tuple[int, int], bytes] = OrderedDict()
        self._closed = False
        self._next_retry = 0.0
        self.frames_sent = 0
        self.bytes_sent = 0
        self.acks_received = 0
        self.reconnects = 0
        self.resend_drops = 0
        # (boot, seq) keys acked since the last take_acks() — how a tree
        # aggregator learns which forwarded envelopes its parent durably
        # accepted.  Bounded: nobody draining must not leak.
        self._ack_history: list[tuple[int, int]] = []

    # -- public surface ----------------------------------------------------
    @property
    def unacked(self) -> int:
        with self._lock:
            return len(self._unacked)

    def take_acks(self) -> list[tuple[int, int]]:
        """Drain the ``(boot, seq)`` keys acked since the last call, in
        ack order.  A tree aggregator polls this each tick to retire its
        forwarded envelopes from the journal."""
        with self._lock:
            out, self._ack_history = self._ack_history, []
        return out

    def send(self, delta: StepDelta) -> bool:
        """Buffer + transmit one delta; returns True if it went out on a
        live connection (False = buffered for resend)."""
        return self.send_bytes(
            delta.to_bytes(version=self.wire_version), delta.boot, delta.seq
        )

    def send_bytes(self, payload: bytes, boot: int, seq: int) -> bool:
        """Lower-level send for pre-serialized payloads; ``(boot, seq)``
        must match the payload's header (they key the ack)."""
        frame = _BOOT_SEQ.pack(boot, seq) + payload
        with self._lock:
            if self._closed:
                raise TransportError("DeltaClient is closed")
            self._unacked[(boot, seq)] = frame
            while len(self._unacked) > self.resend_cap:
                self._unacked.popitem(last=False)
                self.resend_drops += 1
            was_connected = self._sock is not None
            if not self._ensure_connected_locked():
                return False
            if not was_connected:
                # A fresh connection already replayed the whole buffer —
                # including this frame; sending it again here would just
                # burn a duplicate on the dedup watermark.
                return True
            verdict = (self.fault(boot, seq, payload)
                       if self.fault is not None else "pass")
            if verdict == "drop":
                # Sender-side loss: the frame stays buffered; severing
                # the link makes the resend contract deliver it with the
                # next reconnect replay.
                self.faults_injected += 1
                self._disconnect_locked()
                return False
            try:
                _send_frame(self._sock, FRAME_DATA, frame)
                self.frames_sent += 1
                self.bytes_sent += len(payload)
                if verdict == "dup":
                    self.faults_injected += 1
                    _send_frame(self._sock, FRAME_DATA, frame)
                    self.frames_sent += 1
                return True
            except OSError:
                self._disconnect_locked()
                return False

    def flush(self, timeout: float = 30.0) -> bool:
        """Block until every buffered frame is acked (reconnecting and
        replaying as needed).  Returns False on timeout."""
        deadline = self.clock() + timeout
        with self._lock:
            while self._unacked:
                if self.clock() >= deadline:
                    return False
                if self._sock is None:
                    self._next_retry = 0.0  # flush retries eagerly
                    if not self._ensure_connected_locked():
                        self._acked.wait(timeout=self.retry_interval)
                        continue
                self._acked.wait(timeout=0.05)
        return True

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._disconnect_locked()

    def __enter__(self) -> "DeltaClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- internals (all hold self._lock) -----------------------------------
    def _disconnect_locked(self) -> None:
        if self._sock is not None:
            try:
                # shutdown() before close(): the ack reader blocked in
                # recv on this fd pins the file description, so a bare
                # close() would defer the FIN until that recv returns —
                # the server would never learn the connection died (and
                # a reorder holdback flushed on connection death would
                # wait forever).
                self._sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None
        self._gen += 1  # orphan any reader still blocked on the old sock

    def _ensure_connected_locked(self) -> bool:
        if self._sock is not None:
            return True
        now = self.clock()
        if now < self._next_retry:
            return False
        self._next_retry = now + self.retry_interval
        sock = socket.socket(self.family, socket.SOCK_STREAM)
        sock.settimeout(self.connect_timeout)
        try:
            sock.connect(self.sockaddr)
        except OSError:
            sock.close()
            return False
        sock.settimeout(None)
        if self.send_timeout > 0:
            # Bound *sends* only (SO_SNDTIMEO, not settimeout — the ack
            # reader blocks in recv on this same socket and must not get
            # spurious timeouts): a stalled aggregator whose TCP window
            # filled turns into an OSError here, the frame stays in the
            # bounded resend buffer, and the caller's step loop keeps
            # moving instead of hanging inside send().
            try:
                sec = int(self.send_timeout)
                usec = int((self.send_timeout - sec) * 1e6)
                sock.setsockopt(socket.SOL_SOCKET, socket.SO_SNDTIMEO,
                                struct.pack("@ll", sec, usec))
            except OSError:  # pragma: no cover - platform without the opt
                pass
        self._sock = sock
        self._gen += 1
        gen = self._gen
        if self.frames_sent or self.acks_received:
            self.reconnects += 1
        # Replay the unacked tail in order on the fresh connection.
        try:
            for frame in self._unacked.values():
                _send_frame(sock, FRAME_DATA, frame)
                self.frames_sent += 1
                self.bytes_sent += len(frame) - _BOOT_SEQ.size
        except OSError:
            self._disconnect_locked()
            return False
        self._reader = threading.Thread(
            target=self._ack_loop, args=(sock, gen),
            name="DeltaClient.acks", daemon=True,
        )
        self._reader.start()
        return True

    def _ack_loop(self, sock: socket.socket, gen: int) -> None:
        try:
            while True:
                frame = _read_frame(sock)
                if frame is None:
                    break
                ftype, body = frame
                if ftype != FRAME_ACK or len(body) != _BOOT_SEQ.size:
                    break
                boot, seq = _BOOT_SEQ.unpack(body)
                with self._lock:
                    if gen != self._gen:
                        return  # superseded by a reconnect
                    # Cumulative prefix ack: the channel is FIFO and the
                    # server acks every DATA frame, so everything of this
                    # boot at or before ``seq`` in send order is
                    # delivered.  A duplicate ack (a replayed frame the
                    # server acked twice) matches nothing and is a no-op
                    # — it must never pop newer, still-unacked frames.
                    while self._unacked:
                        k = next(iter(self._unacked))
                        if k[0] != boot or k[1] > seq:
                            break
                        self._unacked.popitem(last=False)
                        self.acks_received += 1
                        self._ack_history.append(k)
                    del self._ack_history[: -4 * self.resend_cap or None]
                    self._acked.notify_all()
        except (TransportError, OSError):
            pass
        with self._lock:
            if gen == self._gen:
                self._disconnect_locked()
                self._acked.notify_all()


class ShmRing:
    """Same-machine SPSC shared-memory ring for framed delta payloads.

    One producer process :meth:`push`\\ es ``u32 length | u32 crc32 |
    payload`` records; one consumer :meth:`pop`\\ s them.  Head (read) and
    tail (write) are monotonically increasing u64 byte cursors at offsets
    0 and 8 of the segment; the data region is ``capacity`` bytes after
    the 24-byte header, addressed modulo capacity with byte-granular
    wrap.  A record's bytes are written before the tail cursor is
    published, and with exactly one writer and one reader no lock is
    needed.  Pure Python cannot issue memory fences, so on
    weakly-ordered CPUs a consumer may briefly observe the published
    tail before the record bytes land: the per-record CRC makes that
    safe — :meth:`pop` treats a mismatched record as *not yet visible*
    and returns None (the bytes settle within the store-buffer drain,
    microseconds), raising :class:`TransportError` only if the same
    record stays invalid for a full second of retries (real corruption,
    e.g. a second writer).  ``push`` on a full ring returns False
    (back-pressure, not blocking) — the producer decides whether to
    retry or shed.

    Use :meth:`create` on the owning side and :meth:`attach` (by name) in
    the peer process; the creator :meth:`close`\\ s with ``unlink=True``.
    The header also records the creator's PID so a *cross-process* attach
    can detach itself from Python's shared-memory resource tracker (which
    would otherwise unlink the live segment when the attaching process
    exits — fixed upstream only in 3.13's ``track=False``), while a
    same-process attach leaves tracking alone.
    """

    _HEADER = 32       # u64 head | u64 tail | u64 creator pid | u64 capacity
    _REC_HEAD = 8      # u32 payload length | u32 crc32(payload)
    #: Consecutive failed validations of the *same* head position before
    #: pop() declares the ring corrupt rather than awaiting visibility.
    _MAX_VISIBILITY_RETRIES = 10_000

    def __init__(self, shm, capacity: int, owner: bool) -> None:
        self._shm = shm
        self.capacity = capacity
        self.owner = owner
        self.pushes = 0
        self.pops = 0
        self.full_rejects = 0
        self.frame_errors = 0
        self._retries_at = (-1, 0)  # (head position, failed validations)

    # -- construction ------------------------------------------------------
    @classmethod
    def create(cls, capacity: int = 1 << 20, name: str | None = None) -> "ShmRing":
        from multiprocessing import shared_memory

        shm = shared_memory.SharedMemory(
            name=name, create=True, size=cls._HEADER + int(capacity)
        )
        shm.buf[: cls._HEADER] = bytes(cls._HEADER)  # head = tail = 0
        struct.pack_into("<QQ", shm.buf, 16, os.getpid(), int(capacity))
        return cls(shm, int(capacity), owner=True)

    @classmethod
    def attach(cls, name: str) -> "ShmRing":
        from multiprocessing import shared_memory

        shm = shared_memory.SharedMemory(name=name, create=False)
        creator_pid = struct.unpack_from("<Q", shm.buf, 16)[0]
        if creator_pid != os.getpid():
            try:  # Python <3.13: stop the resource tracker of an
                # *attaching* process from unlinking the live segment when
                # that process exits (the owner unlinks in close()).  A
                # same-process attach keeps its registration — the owner's
                # unlink pairs with it.
                from multiprocessing import resource_tracker

                resource_tracker.unregister(shm._name, "shared_memory")  # noqa: SLF001
            except Exception:  # pragma: no cover - tracker internals moved
                pass
        # The creator's requested capacity, from the header — NOT derived
        # from shm.size: platforms round segments up to page multiples,
        # and both ends must wrap modulo the same number.
        capacity = struct.unpack_from("<Q", shm.buf, 24)[0]
        if not 0 < capacity <= shm.size - cls._HEADER:
            raise TransportError(
                f"shm segment {name!r} header declares capacity {capacity} "
                f"outside the {shm.size}-byte segment — not a ShmRing?"
            )
        return cls(shm, int(capacity), owner=False)

    @property
    def name(self) -> str:
        return self._shm.name

    @property
    def endpoint(self) -> Endpoint:
        """This ring as a typed endpoint (``shm:<segment name>``) — the
        advertisable form a producer hands to :meth:`Endpoint.connect`."""
        return Endpoint("shm", path=self._shm.name)

    # -- cursors -----------------------------------------------------------
    def _head(self) -> int:
        return struct.unpack_from("<Q", self._shm.buf, 0)[0]

    def _tail(self) -> int:
        return struct.unpack_from("<Q", self._shm.buf, 8)[0]

    def _set_head(self, v: int) -> None:
        struct.pack_into("<Q", self._shm.buf, 0, v)

    def _set_tail(self, v: int) -> None:
        struct.pack_into("<Q", self._shm.buf, 8, v)

    def _write(self, pos: int, data: bytes) -> None:
        pos %= self.capacity
        first = min(len(data), self.capacity - pos)
        base = self._HEADER
        self._shm.buf[base + pos : base + pos + first] = data[:first]
        if first < len(data):
            self._shm.buf[base : base + len(data) - first] = data[first:]

    def _read(self, pos: int, count: int) -> bytes:
        pos %= self.capacity
        base = self._HEADER
        first = min(count, self.capacity - pos)
        out = bytes(self._shm.buf[base + pos : base + pos + first])
        if first < count:
            out += bytes(self._shm.buf[base : base + count - first])
        return out

    # -- SPSC operations ---------------------------------------------------
    def push(self, payload: bytes) -> bool:
        """Producer side: frame + write ``payload``; False if the ring
        lacks space (record never partially visible)."""
        need = self._REC_HEAD + len(payload)
        if need > self.capacity:
            raise ValueError(
                f"payload of {len(payload)} bytes exceeds ring capacity"
            )
        head, tail = self._head(), self._tail()
        if self.capacity - (tail - head) < need:
            self.full_rejects += 1
            return False
        self._write(tail, struct.pack("<II", len(payload),
                                      zlib.crc32(payload)))
        self._write(tail + self._REC_HEAD, payload)
        self._set_tail(tail + need)  # publish
        self.pushes += 1
        return True

    def _not_yet_visible(self, head: int) -> None:
        """A record that fails validation under a published tail is, on a
        healthy SPSC ring, a store still draining on a weakly-ordered
        CPU: back off and let the caller retry.  The same head position
        failing persistently is real corruption."""
        pos, n = self._retries_at
        n = n + 1 if pos == head else 1
        self._retries_at = (head, n)
        if n > self._MAX_VISIBILITY_RETRIES:
            raise TransportError(
                "shm ring corrupt: record at head failed validation "
                f"{n} times (length/crc never settled)"
            )

    def pop(self) -> bytes | None:
        """Consumer side: next payload; None if the ring is empty or the
        head record's bytes are not yet fully visible (retry later)."""
        head, tail = self._head(), self._tail()
        if tail == head:
            return None
        length, crc = struct.unpack("<II", self._read(head, self._REC_HEAD))
        if self._REC_HEAD + length > tail - head:
            self._not_yet_visible(head)
            return None
        payload = self._read(head + self._REC_HEAD, length)
        if zlib.crc32(payload) != crc:
            self._not_yet_visible(head)
            return None
        self._retries_at = (-1, 0)
        self._set_head(head + self._REC_HEAD + length)
        self.pops += 1
        return payload

    def drain_into(self, aggregator, max_payloads: int | None = None) -> int:
        """Consumer convenience: pop and ingest until empty.  A payload
        failing wire validation is dropped and counted in
        ``frame_errors`` rather than poisoning the tick (the socket
        server's ``drain_into`` contract)."""
        rows = 0
        n = 0
        while max_payloads is None or n < max_payloads:
            payload = self.pop()
            if payload is None:
                break
            try:
                rows += aggregator.ingest(payload)
            except WireFormatError:
                self.frame_errors += 1
            n += 1
        return rows

    def close(self, unlink: bool | None = None) -> None:
        if unlink is None:
            unlink = self.owner
        self._shm.close()
        if unlink:
            try:
                self._shm.unlink()
            except FileNotFoundError:
                pass

    def __enter__(self) -> "ShmRing":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class RingSender:
    """Adapter giving :class:`ShmRing` the producer-side ``send(delta)``
    surface of :class:`DeltaClient` (so ``Diagnosis.forward(...)`` and
    the launcher treat socket and ring paths uniformly).  A full ring
    retries briefly, then sheds the delta (``shed`` counter) — the
    same-machine consumer draining each tick makes sustained fullness an
    aggregator stall, which telemetry must survive.  The retry wait is
    the only wall-clock dependence on the whole shm path (``ShmRing``
    itself spins on visibility retries, never on time) — inject
    ``sleep=`` to run it at simulated time."""

    def __init__(self, ring: ShmRing, *, wire_version: int | None = None,
                 retry: float = 0.01, sleep=time.sleep) -> None:
        self.ring = ring
        self.wire_version = None if wire_version is None else int(wire_version)
        self.retry = float(retry)
        self.sleep = sleep
        self.shed = 0

    def send(self, delta: StepDelta) -> bool:
        return self.send_bytes(
            delta.to_bytes(version=self.wire_version), delta.boot, delta.seq
        )

    def send_bytes(self, payload: bytes, boot: int, seq: int) -> bool:
        """Pre-serialized payload push (surface parity with
        :meth:`DeltaClient.send_bytes` so tree aggregators treat socket
        and ring parents uniformly).  ``(boot, seq)`` ride inside the
        payload; a successful push *is* the delivery — there is no ack
        channel, so consumers treating the return value as the ack get
        at-most-once on shed, exactly the ring's contract."""
        if self.ring.push(payload):
            return True
        self.sleep(self.retry)
        if self.ring.push(payload):
            return True
        self.shed += 1
        return False

    def flush(self, timeout: float = 0.0) -> bool:  # symmetry with DeltaClient
        return True

    def close(self) -> None:
        self.ring.close(unlink=False)
