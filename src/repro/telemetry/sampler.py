"""System utilization samplers — mpstat/iostat/sar analogs over /proc.

Paper §III-A.1 samples user CPU time (MPSTAT), I/O time (IOSTAT) and network
byte rate (SAR) at 1 Hz; the per-task features are the window averages of
those samples (Eq. 1-3).  Here the same three quantities are read straight
from ``/proc/stat``, ``/proc/diskstats`` and ``/proc/net/dev`` — no external
tools — and pushed into a :class:`ResourceTimeline`.

Robustness: in containers and on non-Linux hosts some of those files do not
exist (``/proc/diskstats`` is the usual casualty).  The sampler degrades
per metric instead of dying: a metric whose source file is missing or
unreadable is skipped for that tick (its Eq. 6 timeline simply has a gap —
the analyzer's edge detection already treats missing windows as "keep"),
the other metrics keep flowing, and :attr:`SystemSampler.metric_health` /
:meth:`SystemSampler.healthy` expose which sources are currently dark so a
supervisor can alarm on a starved timeline instead of silently losing the
``sampler-<host>`` thread.  All ``/proc`` paths are injectable for tests
(fake-/proc fixtures) and exotic mount points.

Overhead (paper Table VII analog, measured by ``benchmarks/table7_overhead``):
one read+parse of the three files per second, <1% of one core.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass

from .timeline import ResourceTimeline

_PROC_STAT = "/proc/stat"
_PROC_DISKSTATS = "/proc/diskstats"
_PROC_NETDEV = "/proc/net/dev"

# Device prefixes that are not physical disks.
_SKIP_DISK_PREFIXES = ("loop", "ram", "zram", "dm-", "sr", "fd", "md")

METRICS = ("cpu", "disk", "network")


@dataclass(frozen=True)
class CpuSample:
    user: int   # user + nice jiffies
    total: int  # all jiffies


@dataclass(frozen=True)
class DiskSample:
    io_ticks_ms: int  # time spent doing I/O, summed over physical devices


@dataclass(frozen=True)
class NetSample:
    bytes_total: int  # rx + tx over non-loopback interfaces


def read_cpu_sample(path: str = _PROC_STAT) -> CpuSample:
    with open(path) as f:
        line = f.readline()
    parts = line.split()
    vals = [int(x) for x in parts[1:]]
    user = vals[0] + vals[1]  # user + nice
    return CpuSample(user=user, total=sum(vals))


def read_disk_sample(path: str = _PROC_DISKSTATS) -> DiskSample:
    ticks = 0
    with open(path) as f:
        for line in f:
            parts = line.split()
            if len(parts) < 13:
                continue
            name = parts[2]
            if name.startswith(_SKIP_DISK_PREFIXES):
                continue
            # Only whole devices (skip partitions like sda1) — heuristic: skip
            # names ending in a digit unless nvme ('nvme0n1' is a whole device).
            if name[-1].isdigit() and not name.startswith("nvme"):
                continue
            if name.startswith("nvme") and "p" in name.split("n", 2)[-1]:
                continue
            ticks += int(parts[12])  # field 13: io_ticks (ms)
    return DiskSample(io_ticks_ms=ticks)


def read_net_sample(path: str = _PROC_NETDEV) -> NetSample:
    total = 0
    with open(path) as f:
        lines = f.readlines()[2:]
    for line in lines:
        if ":" not in line:
            continue
        name, rest = line.split(":", 1)
        if name.strip() == "lo":
            continue
        parts = rest.split()
        total += int(parts[0]) + int(parts[8])  # rx_bytes + tx_bytes
    return NetSample(bytes_total=total)


class SystemSampler:
    """1 Hz background sampler emitting Eq. 1-3 quantities into a timeline.

    Emitted metrics (matching the feature schema):
      cpu     — user-time fraction over the last interval (Eq. 1 integrand)
      disk    — I/O-time fraction over the last interval (Eq. 2 integrand)
      network — bytes/sec over the last interval (Eq. 3 integrand)

    Each metric is sampled independently; a missing/unreadable source file
    (``OSError``, including ``FileNotFoundError`` inside containers, and
    ``ValueError`` from a malformed line) marks that metric unhealthy for
    the tick and the sampler moves on — the thread never dies on a bad
    ``/proc``.  Health is visible via :attr:`metric_health` (metric →
    bool, last tick), :meth:`healthy` (all sources readable) and
    :attr:`read_errors` (cumulative per-metric failure counts).
    """

    def __init__(
        self,
        node: str,
        timeline: ResourceTimeline,
        interval: float = 1.0,
        clock=time.time,
        *,
        proc_stat: str = _PROC_STAT,
        proc_diskstats: str = _PROC_DISKSTATS,
        proc_netdev: str = _PROC_NETDEV,
    ) -> None:
        self.node = node
        self.timeline = timeline
        self.interval = interval
        self.clock = clock
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        # metric → (reader, source path); per-metric previous samples so one
        # dark source cannot stall the delta pipeline of the others.
        self._sources = {
            "cpu": (read_cpu_sample, proc_stat),
            "disk": (read_disk_sample, proc_diskstats),
            "network": (read_net_sample, proc_netdev),
        }
        self._prev: dict[str, tuple[object, float]] = {}
        self.metric_health: dict[str, bool] = {m: True for m in METRICS}
        self.read_errors: dict[str, int] = {m: 0 for m in METRICS}
        self.ticks = 0
        # Failures past the readers (e.g. a timeline sink raising):
        # tick_errors counts them cumulatively; last_tick_ok tracks only
        # the most recent tick so health recovers once the sink does
        # (mirroring the per-tick semantics of metric_health).
        self.tick_errors = 0
        self.last_tick_ok = True

    # -- health --------------------------------------------------------------
    def healthy(self) -> bool:
        """True iff every metric source was readable on the last tick and
        the last tick did not fail past the readers (sink/clock errors)."""
        return all(self.metric_health.values()) and self.last_tick_ok

    def missing_metrics(self) -> list[str]:
        return [m for m in METRICS if not self.metric_health[m]]

    # -- manual stepping (used by tests and by the serve loop) ---------------
    def sample_once(self) -> None:
        now = self.clock()
        cur: dict[str, object] = {}
        for metric, (reader, path) in self._sources.items():
            try:
                cur[metric] = reader(path)
                self.metric_health[metric] = True
            except (OSError, ValueError, IndexError):
                # Missing /proc file (containers), transient read hiccup, or
                # a malformed line: skip this metric, keep the rest alive.
                self.metric_health[metric] = False
                self.read_errors[metric] += 1
        self.ticks += 1
        for metric, sample in cur.items():
            prev = self._prev.get(metric)
            self._prev[metric] = (sample, now)
            if prev is None:
                continue
            psample, pt = prev
            dt = max(now - pt, 1e-9)
            if metric == "cpu":
                d_total = max(sample.total - psample.total, 1)
                value = max((sample.user - psample.user) / d_total, 0.0)
            elif metric == "disk":
                value = max(
                    min((sample.io_ticks_ms - psample.io_ticks_ms)
                        / (dt * 1000.0), 1.0),
                    0.0,
                )
            else:  # network
                value = max(
                    (sample.bytes_total - psample.bytes_total) / dt, 0.0
                )
            self.timeline.record(self.node, metric, now, value)

    # -- background thread -----------------------------------------------------
    def start(self) -> "SystemSampler":
        if self._thread is not None:
            raise RuntimeError("sampler already started")
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"sampler-{self.node}")
        self._thread.start()
        return self

    def _run(self) -> None:
        while True:
            try:
                self.sample_once()
                self.last_tick_ok = True
            except Exception:
                # Belt and braces: per-metric errors are handled inside
                # sample_once; anything else (e.g. a timeline sink bug)
                # must not kill the thread — but it must not be invisible
                # either, so it trips healthy() until a tick succeeds.
                self.tick_errors += 1
                self.last_tick_ok = False
            if self._stop.wait(self.interval):
                return

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def __enter__(self) -> "SystemSampler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
