"""System utilization samplers — mpstat/iostat/sar analogs over /proc.

Paper §III-A.1 samples user CPU time (MPSTAT), I/O time (IOSTAT) and network
byte rate (SAR) at 1 Hz; the per-task features are the window averages of
those samples (Eq. 1-3).  Here the same three quantities are read straight
from ``/proc/stat``, ``/proc/diskstats`` and ``/proc/net/dev`` — no external
tools — and pushed into a :class:`ResourceTimeline`.

Overhead (paper Table VII analog, measured by ``benchmarks/table7_overhead``):
one read+parse of the three files per second, <1% of one core.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass

from .timeline import ResourceTimeline

_PROC_STAT = "/proc/stat"
_PROC_DISKSTATS = "/proc/diskstats"
_PROC_NETDEV = "/proc/net/dev"

# Device prefixes that are not physical disks.
_SKIP_DISK_PREFIXES = ("loop", "ram", "zram", "dm-", "sr", "fd", "md")


@dataclass(frozen=True)
class CpuSample:
    user: int   # user + nice jiffies
    total: int  # all jiffies


@dataclass(frozen=True)
class DiskSample:
    io_ticks_ms: int  # time spent doing I/O, summed over physical devices


@dataclass(frozen=True)
class NetSample:
    bytes_total: int  # rx + tx over non-loopback interfaces


def read_cpu_sample(path: str = _PROC_STAT) -> CpuSample:
    with open(path) as f:
        line = f.readline()
    parts = line.split()
    vals = [int(x) for x in parts[1:]]
    user = vals[0] + vals[1]  # user + nice
    return CpuSample(user=user, total=sum(vals))


def read_disk_sample(path: str = _PROC_DISKSTATS) -> DiskSample:
    ticks = 0
    with open(path) as f:
        for line in f:
            parts = line.split()
            if len(parts) < 13:
                continue
            name = parts[2]
            if name.startswith(_SKIP_DISK_PREFIXES):
                continue
            # Only whole devices (skip partitions like sda1) — heuristic: skip
            # names ending in a digit unless nvme ('nvme0n1' is a whole device).
            if name[-1].isdigit() and not name.startswith("nvme"):
                continue
            if name.startswith("nvme") and "p" in name.split("n", 2)[-1]:
                continue
            ticks += int(parts[12])  # field 13: io_ticks (ms)
    return DiskSample(io_ticks_ms=ticks)


def read_net_sample(path: str = _PROC_NETDEV) -> NetSample:
    total = 0
    with open(path) as f:
        lines = f.readlines()[2:]
    for line in lines:
        if ":" not in line:
            continue
        name, rest = line.split(":", 1)
        if name.strip() == "lo":
            continue
        parts = rest.split()
        total += int(parts[0]) + int(parts[8])  # rx_bytes + tx_bytes
    return NetSample(bytes_total=total)


class SystemSampler:
    """1 Hz background sampler emitting Eq. 1-3 quantities into a timeline.

    Emitted metrics (matching the feature schema):
      cpu     — user-time fraction over the last interval (Eq. 1 integrand)
      disk    — I/O-time fraction over the last interval (Eq. 2 integrand)
      network — bytes/sec over the last interval (Eq. 3 integrand)
    """

    def __init__(
        self,
        node: str,
        timeline: ResourceTimeline,
        interval: float = 1.0,
        clock=time.time,
    ) -> None:
        self.node = node
        self.timeline = timeline
        self.interval = interval
        self.clock = clock
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._prev: tuple[CpuSample, DiskSample, NetSample, float] | None = None

    # -- manual stepping (used by tests and by the serve loop) ---------------
    def sample_once(self) -> None:
        now = self.clock()
        cur = (read_cpu_sample(), read_disk_sample(), read_net_sample(), now)
        if self._prev is not None:
            pc, pd, pn, pt = self._prev
            cc, cd, cn, _ = cur
            dt = max(now - pt, 1e-9)
            d_total = max(cc.total - pc.total, 1)
            cpu = (cc.user - pc.user) / d_total
            disk = min((cd.io_ticks_ms - pd.io_ticks_ms) / (dt * 1000.0), 1.0)
            net = (cn.bytes_total - pn.bytes_total) / dt
            self.timeline.record(self.node, "cpu", now, max(cpu, 0.0))
            self.timeline.record(self.node, "disk", now, max(disk, 0.0))
            self.timeline.record(self.node, "network", now, max(net, 0.0))
        self._prev = cur

    # -- background thread -----------------------------------------------------
    def start(self) -> "SystemSampler":
        if self._thread is not None:
            raise RuntimeError("sampler already started")
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"sampler-{self.node}")
        self._thread.start()
        return self

    def _run(self) -> None:
        self.sample_once()
        while not self._stop.wait(self.interval):
            try:
                self.sample_once()
            except OSError:
                # /proc hiccup: skip the sample rather than die.
                continue

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def __enter__(self) -> "SystemSampler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
