"""Step-scoped telemetry: turn one host's training/serving step into a
BigRoots :class:`TaskRecord`.

This is the "Spark log file" layer of the paper, adapted to SPMD training
(DESIGN.md §2): per step, each host times its local phases (data load, h2d,
compute-until-barrier, d2h, checkpoint), accumulates byte counters and GC
pauses, and emits a TaskRecord whose stage is the step window.  The
*pre-barrier duration* (host-local work) is the task duration — the honest
analog of a Spark task's runtime under a synchronous collective.
"""
from __future__ import annotations

import gc
import time
from contextlib import contextmanager
from dataclasses import dataclass, field

from ..core.features import JAX_FEATURES, FeatureSchema
from ..core.frame import TraceStore
from ..core.window import SlidingStageWindow
from .timeline import ResourceTimeline


class GcTimer:
    """Accumulates Python GC pause time via gc callbacks (the 'JVM GC time'
    analog for a Python-driven input pipeline)."""

    def __init__(self, clock=time.perf_counter) -> None:
        self._clock = clock
        self._start: float | None = None
        self.total = 0.0
        self._installed = False

    def _cb(self, phase: str, info: dict) -> None:
        if phase == "start":
            self._start = self._clock()
        elif phase == "stop" and self._start is not None:
            self.total += self._clock() - self._start
            self._start = None

    def install(self) -> "GcTimer":
        if not self._installed:
            gc.callbacks.append(self._cb)
            self._installed = True
        return self

    def uninstall(self) -> None:
        if self._installed:
            gc.callbacks.remove(self._cb)
            self._installed = False

    def take(self) -> float:
        """Return accumulated pause time and reset."""
        t, self.total = self.total, 0.0
        return t

    def __enter__(self) -> "GcTimer":
        return self.install()

    def __exit__(self, *exc) -> None:
        self.uninstall()


@dataclass
class StepScope:
    """Mutable accumulator for one step on one host."""

    node: str
    step: int
    start: float
    clock: object
    phases: dict[str, float] = field(default_factory=dict)
    counters: dict[str, float] = field(default_factory=dict)
    locality: int = 0
    end: float | None = None

    @contextmanager
    def phase(self, name: str):
        t0 = self.clock()
        try:
            yield
        finally:
            self.phases[name] = self.phases.get(name, 0.0) + (self.clock() - t0)

    def add(self, counter: str, value: float) -> None:
        self.counters[counter] = self.counters.get(counter, 0.0) + value

    def set_locality(self, locality: int) -> None:
        self.locality = locality


class StepTelemetry:
    """Per-host step-record emitter.

    Steps ingest straight into a columnar
    :class:`~repro.core.frame.TraceStore` (``self.trace``) — no per-step
    dataclass materialization on the hot path; ``trace`` still supports the
    full Trace API (``stages()``/``stage()``/``dump_jsonl``) and stages
    expose a ``TaskRecord`` view for compatibility.

    Usage::

        telem = StepTelemetry(node="host3", timeline=tl)
        with telem.step(i) as s:
            with s.phase("data_load"): batch = next(it)
            s.add("read_bytes", batch.nbytes)
            with s.phase("h2d"): batch = jax.device_put(batch)
            with s.phase("compute"): state, loss = train_step(state, batch)
        trace = telem.trace

    Streaming mode (``streaming=True``) additionally mirrors every emitted
    row into ``self.live_window`` — a
    :class:`~repro.core.window.SlidingStageWindow` holding the last
    ``window`` steps (override with ``stream_max_rows``/``stream_span``)
    with running aggregates, so an analyzer can run *inside* the loop at
    every step for O(changed rows) instead of resealing the stage::

        telem = StepTelemetry("host3", timeline=tl, streaming=True)
        stream = RootCauseStream(BigRootsAnalyzer(JAX_FEATURES, timelines=tl),
                                 telem.live_window)
        with telem.step(i) as s: ...
        for cause in stream.step():  # newly confirmed causes, live
            ...
    """

    # phase name → TIME feature name in the JAX schema
    _PHASE_FEATURES = {
        "data_load": "data_load_time",
        "h2d": "h2d_time",
        "d2h": "d2h_time",
        "ckpt": "ckpt_time",
    }
    _RESOURCE_METRICS = ("cpu", "disk", "network")

    def __init__(
        self,
        node: str,
        timeline: ResourceTimeline | None = None,
        window: int = 1,
        clock=time.time,
        gc_timer: GcTimer | None = None,
        schema: FeatureSchema | None = None,
        streaming: bool = False,
        stream_max_rows: int | None = None,
        stream_span: float | None = None,
        stream_quantile: float = 0.9,
    ) -> None:
        self.node = node
        self.timeline = timeline
        self.window = max(int(window), 1)
        self.clock = clock
        self.gc_timer = gc_timer
        self.schema = schema or JAX_FEATURES
        self.trace = TraceStore(self.schema)
        self.live_window: SlidingStageWindow | None = None
        if streaming:
            self.live_window = SlidingStageWindow(
                f"{node}/live", self.schema,
                span=stream_span,
                max_rows=(stream_max_rows if stream_max_rows is not None
                          else self.window),
                quantile=stream_quantile,
            )

    def stage_id_for(self, step: int) -> str:
        """Stage = window of `window` consecutive steps (peer pooling)."""
        return f"steps_{(step // self.window) * self.window:06d}"

    @contextmanager
    def step(self, step: int):
        scope = StepScope(node=self.node, step=step, start=self.clock(), clock=self.clock)
        if self.gc_timer is not None:
            self.gc_timer.take()  # reset accumulator at step start
        try:
            yield scope
        finally:
            scope.end = self.clock()
            self._emit(scope)

    # -- record construction ----------------------------------------------------
    def _emit(self, scope: StepScope) -> None:
        features: dict[str, float] = {}
        for phase, feat in self._PHASE_FEATURES.items():
            if phase in scope.phases:
                features[feat] = scope.phases[phase]
        if self.gc_timer is not None:
            features["gc_time"] = self.gc_timer.take()
        features.update(scope.counters)

        # Resource features: Eq. 1-3 window means over the task interval.
        if self.timeline is not None:
            for metric in self._RESOURCE_METRICS:
                val = self.timeline.window_mean(self.node, metric, scope.start, scope.end)
                if val is not None:
                    features[metric] = val

        task_id = f"{self.node}/step{scope.step:06d}"
        self.trace.add_row(
            task_id=task_id,
            stage_id=self.stage_id_for(scope.step),
            node=self.node,
            start=scope.start,
            end=scope.end,
            locality=scope.locality,
            features=features,
        )
        if self.live_window is not None:
            self.live_window.add_row(
                task_id, self.node, scope.start, scope.end,
                scope.locality, features,
            )
            self.live_window.advance(scope.end)

    # -- merging (multi-host traces are concatenated by the launcher) -----------
    def merge_into(self, trace) -> None:
        """Append this host's records into ``trace`` (Trace or TraceStore)."""
        for stage in self.trace.stages():
            for task in stage.tasks:
                trace.add_task(task)
