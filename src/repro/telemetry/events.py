"""Step-scoped telemetry: turn one host's training/serving step into a
BigRoots :class:`TaskRecord`.

This is the "Spark log file" layer of the paper, adapted to SPMD training
(DESIGN.md §2): per step, each host times its local phases (data load, h2d,
compute-until-barrier, d2h, checkpoint), accumulates byte counters and GC
pauses, and emits a TaskRecord whose stage is the step window.  The
*pre-barrier duration* (host-local work) is the task duration — the honest
analog of a Spark task's runtime under a synchronous collective.

Fleet wire format
-----------------
Cross-node comparison is the whole BigRoots premise, so per-host telemetry
must reach a central aggregator.  :class:`StepDelta` is the unit shipped:
the columnar block of rows a host emitted since its last drain, grouped by
stage.  Two self-describing wire encodings exist (dispatched on the 4-byte
magic; ``docs/wire_format.md`` is the normative spec):

- **v1** (``BRD1``): one small JSON header (strings: host, stage ids, task
  ids, node names, column names) followed by raw little-endian numeric
  buffers — no pickling, no per-row framing, and a decode that is a
  handful of ``np.frombuffer`` views.
- **v2** (``BRD2``, the :meth:`StepDelta.to_bytes` default): the same
  header and column order, but every numeric column is delta-compressed —
  XOR against the previous row, a packed changed-row bitmask, byte-plane
  transposed residuals — and the whole body is DEFLATE-compressed.  A
  host's hot columns are near-constant step to step (constant batch
  bytes, quantized /proc counters, zero GC pauses), so most columns
  collapse to a bitmask.  The encoding is stateless per payload: a
  resent or reordered delta decodes without any reference state.
- **v3** (``BRD3``): v2's exact body layout plus an *attribution block*
  — the JSON header gains a ``causes`` list of wire-form attributed
  :class:`~repro.core.analyzer.RootCause` records (see
  :func:`repro.core.analyzer.cause_to_wire`), so a leaf or mid-tier
  diagnosis can ship its what-if priced causes upstream and have them
  survive fan-in tree aggregation byte-identically (``BRDF`` forwards
  inner payloads verbatim).  v3 is emitted *only when a delta actually
  carries causes*: with attribution off :meth:`StepDelta.to_bytes`
  produces v2 bytes unchanged, so v2-only readers never see a ``BRD3``
  frame from an unattributed fleet.

A per-column ``present`` mask rides along in both versions so "recorded
as 0.0" and "absent" stay distinct across the wire (the same invariant
the columnar substrate keeps in memory).  :meth:`StepDelta.from_bytes`
parses both versions, validating every header-declared length against the
actual buffer before touching numpy — a truncated or corrupt frame raises
:class:`WireFormatError`, never a reshape error deep in merge.
``StepTelemetry(wire=True)`` accumulates pending rows and
:meth:`StepTelemetry.drain_delta` cuts a delta; the launcher-side consumer
is :class:`repro.serve.FleetAggregator`, and
:mod:`repro.telemetry.transport` carries payloads across processes.
"""
from __future__ import annotations

import gc
import json
import struct
import time
import zlib
from contextlib import contextmanager
from dataclasses import dataclass, field

import numpy as np

from ..core.features import JAX_FEATURES, FeatureSchema
from ..core.frame import TraceStore
from ..core.window import SlidingStageWindow, StreamingTraceStore
from .timeline import ResourceTimeline

WIRE_V1_MAGIC = b"BRD1"
WIRE_V2_MAGIC = b"BRD2"
WIRE_V3_MAGIC = b"BRD3"
WIRE_FWD_MAGIC = b"BRDF"
_WIRE_MAGIC = WIRE_V1_MAGIC  # back-compat alias

#: Refuse headers claiming more than this many rows in one stage block —
#: far above any real drain, and it bounds what a corrupt length field can
#: make the decoder allocate.
_MAX_ROWS_PER_STAGE = 1 << 24

#: Refuse v2 frames declaring a decompressed body beyond this: the
#: declared length caps decompression *before* it runs, so a small
#: high-ratio DEFLATE bomb cannot make the decoder materialize gigabytes.
_MAX_BODY_BYTES = 1 << 30

#: Refuse v3 headers carrying more than this many attributed causes —
#: far above any real diagnosis tick, bounding allocation from a corrupt
#: or hostile header.
_MAX_WIRE_CAUSES = 1 << 16


class WireFormatError(ValueError):
    """A wire payload failed structural validation: bad magic, truncated
    or over-long buffers vs the header-declared lengths, a malformed JSON
    header, or a corrupt compression stream.  Subclasses ``ValueError``
    so pre-existing ``except ValueError`` callers keep working."""


def _need(buf_len: int, off: int, count: int, what: str) -> None:
    if count < 0 or off + count > buf_len:
        raise WireFormatError(
            f"truncated StepDelta payload: {what} needs {count} bytes at "
            f"offset {off} but only {buf_len - off} remain"
        )


# -- v2 column codecs --------------------------------------------------------
# Each numeric column is encoded as: XOR of every row against the previous
# row (first row against 0), a packed bitmask of rows whose XOR is nonzero,
# a u32 count of those rows, then the changed rows' XOR words transposed
# into byte planes (all byte-0s, then all byte-1s, ...).  Near-constant
# columns collapse to the bitmask; for varying columns the transpose groups
# the shared sign/exponent bytes into runs the final DEFLATE pass removes.
# Decode is exact: scatter residuals, prefix-XOR, reinterpret — bit
# identical to the raw column, NaNs and signed zeros included.

def _delta_encode(words: np.ndarray) -> bytes:
    """``words``: little-endian unsigned view of one column (u64/u16)."""
    n = words.size
    x = words.copy()
    x[1:] ^= words[:-1]
    changed = x != 0
    k = int(changed.sum())
    resid = np.ascontiguousarray(x[changed]).view(np.uint8)
    planes = resid.reshape(k, words.dtype.itemsize).T if k else resid
    return (np.packbits(changed).tobytes() + struct.pack("<I", k)
            + np.ascontiguousarray(planes).tobytes())


def _delta_decode(buf: bytes, off: int, n: int, dtype: str,
                  what: str) -> tuple[np.ndarray, int]:
    """Inverse of :func:`_delta_encode`; returns (column, new offset)."""
    itemsize = np.dtype(dtype).itemsize
    nmask = (n + 7) // 8
    _need(len(buf), off, nmask + 4, f"{what} changed-mask")
    changed = np.unpackbits(
        np.frombuffer(buf, np.uint8, nmask, off), count=n
    ).astype(bool)
    off += nmask
    (k,) = struct.unpack_from("<I", buf, off)
    off += 4
    if k != int(changed.sum()):
        raise WireFormatError(
            f"corrupt {what}: {k} residuals declared but the changed-mask "
            f"has {int(changed.sum())} set bits"
        )
    _need(len(buf), off, k * itemsize, f"{what} residuals")
    planes = np.frombuffer(buf, np.uint8, k * itemsize, off)
    off += k * itemsize
    x = np.zeros(n, dtype=dtype)
    if k:
        x[changed] = np.ascontiguousarray(
            planes.reshape(itemsize, k).T
        ).view(dtype).ravel()
    return np.bitwise_xor.accumulate(x), off


class GcTimer:
    """Accumulates Python GC pause time via gc callbacks (the 'JVM GC time'
    analog for a Python-driven input pipeline)."""

    def __init__(self, clock=time.perf_counter) -> None:
        self._clock = clock
        self._start: float | None = None
        self.total = 0.0
        self._installed = False

    def _cb(self, phase: str, info: dict) -> None:
        if phase == "start":
            self._start = self._clock()
        elif phase == "stop" and self._start is not None:
            self.total += self._clock() - self._start
            self._start = None

    def install(self) -> "GcTimer":
        if not self._installed:
            gc.callbacks.append(self._cb)
            self._installed = True
        return self

    def uninstall(self) -> None:
        if self._installed:
            gc.callbacks.remove(self._cb)
            self._installed = False

    def take(self) -> float:
        """Return accumulated pause time and reset."""
        t, self.total = self.total, 0.0
        return t

    def __enter__(self) -> "GcTimer":
        return self.install()

    def __exit__(self, *exc) -> None:
        self.uninstall()


@dataclass
class StageDelta:
    """One stage's slice of a :class:`StepDelta`: parallel columns for the
    rows a host added to that stage since the last drain."""

    stage_id: str
    task_ids: list[str]
    nodes: list[str]
    starts: np.ndarray          # float64 [m]
    ends: np.ndarray            # float64 [m]
    locality: np.ndarray        # int16   [m]
    columns: dict[str, np.ndarray]   # float64 [m] per feature name
    present: dict[str, np.ndarray]   # bool    [m] per feature name

    def __len__(self) -> int:
        return len(self.task_ids)


@dataclass
class StepDelta:
    """A host's telemetry rows since its last drain, as columnar blocks per
    stage — the unit a sharded fleet ships to the launcher-side
    :class:`~repro.serve.FleetAggregator` (see module docstring for the
    wire layout).

    ``seq`` increases by one per drain within a producer incarnation;
    ``boot`` identifies the incarnation itself (a nanosecond timestamp
    taken when the :class:`StepTelemetry` was created).  Together they let
    the consumer tell a *redelivered* delta (same boot, seq not newer →
    drop) from a *restarted host* (newer boot → accept and reset) without
    any handshake.

    ``causes`` carries attributed root causes in wire form (dicts from
    :func:`repro.core.analyzer.cause_to_wire`) for the v3 attribution
    block; it is empty on every v1/v2 payload and on any delta cut by
    an attribution-off pipeline."""

    host: str
    seq: int
    stages: list[StageDelta]
    boot: int = 0
    causes: list = field(default_factory=list)

    @property
    def num_rows(self) -> int:
        return sum(len(s) for s in self.stages)

    def apply_to(self, store: StreamingTraceStore) -> int:
        """Ingest every stage block into ``store`` (columnar bulk path,
        present masks preserved).  Returns rows ingested (late rows behind
        a window's watermark are dropped by the window, as ever)."""
        ingested = 0
        for s in self.stages:
            ingested += store.add_rows(
                s.stage_id, s.task_ids, s.nodes, s.starts, s.ends,
                s.locality, feature_columns=s.columns,
                present_columns=s.present,
            )
        return ingested

    # -- wire format -------------------------------------------------------
    def _header_bytes(self, *, with_causes: bool = False) -> bytes:
        header = {
            "host": self.host,
            "seq": self.seq,
            "boot": self.boot,
            "stages": [
                {
                    "stage_id": s.stage_id,
                    "n": len(s),
                    "task_ids": s.task_ids,
                    "nodes": s.nodes,
                    "columns": list(s.columns),
                }
                for s in self.stages
            ],
        }
        if with_causes:
            header["causes"] = list(self.causes)
        return json.dumps(header, separators=(",", ":")).encode()

    def _canonical_column(self, s: "StageDelta", name: str) -> np.ndarray:
        """Column values with masked-out slots forced to 0.0: whatever the
        producer left in the buffer, the wire carries the canonical form
        (the decoder re-imposes the mask either way)."""
        vals = np.asarray(s.columns[name], dtype="<f8")
        mask = s.present.get(name)
        if mask is not None:
            vals = np.where(np.asarray(mask, dtype=bool), vals, 0.0)
        return np.ascontiguousarray(vals, dtype="<f8")

    def _present_column(self, s: "StageDelta", name: str) -> np.ndarray:
        return np.ascontiguousarray(
            s.present.get(name, np.ones(len(s), dtype=bool)), dtype="u1"
        )

    def to_bytes(self, version: int | None = None) -> bytes:
        """Serialize this delta as a self-contained wire payload.

        ``version=None`` (default) auto-selects: version 2 normally,
        upgraded to version 3 iff ``causes`` is non-empty — so an
        attribution-off pipeline emits v2 bytes unchanged, byte for byte.
        ``version=3``: magic ``BRD3``, otherwise identical framing to v2
        (u32 decompressed body length, DEFLATE stream of [u32 header
        length, JSON header, per-stage delta-compressed column sections])
        except the JSON header carries a ``causes`` list of wire-form
        attributed root causes.  ``version=2``: magic ``BRD2``, same
        framing, no causes (requesting it with causes attached raises
        ``ValueError`` — the attribution block cannot be silently
        dropped).  ``version=1``: magic ``BRD1``, u32 header length,
        JSON header, then per stage the raw ``<f8/<i2/u1`` column
        buffers in header order.  All versions are stateless per payload
        and decoded by :meth:`from_bytes` off the magic alone (the
        deflate body is validated against its declared length).  Column
        values where ``present`` is False are encoded as 0.0 (the
        decoder re-imposes the mask)."""
        if version is None:
            version = 3 if self.causes else 2
        if version in (1, 2) and self.causes:
            raise ValueError(
                f"StepDelta carries {len(self.causes)} attributed causes; "
                f"wire version {version} cannot encode them (use version 3 "
                "or leave version unset)"
            )
        if version == 1:
            head = self._header_bytes()
            parts = [WIRE_V1_MAGIC, struct.pack("<I", len(head)), head]
            for s in self.stages:
                parts.append(np.ascontiguousarray(s.starts, dtype="<f8").tobytes())
                parts.append(np.ascontiguousarray(s.ends, dtype="<f8").tobytes())
                parts.append(np.ascontiguousarray(s.locality, dtype="<i2").tobytes())
                for name in s.columns:
                    parts.append(self._canonical_column(s, name).tobytes())
                    parts.append(self._present_column(s, name).tobytes())
            return b"".join(parts)
        if version not in (2, 3):
            raise ValueError(f"unknown StepDelta wire version {version!r}")
        head = self._header_bytes(with_causes=(version == 3))
        parts = [struct.pack("<I", len(head)), head]
        for s in self.stages:
            for col in (np.ascontiguousarray(s.starts, dtype="<f8"),
                        np.ascontiguousarray(s.ends, dtype="<f8")):
                parts.append(_delta_encode(col.view("<u8")))
            loc = np.ascontiguousarray(s.locality, dtype="<i2")
            parts.append(_delta_encode(loc.view("<u2")))
            for name in s.columns:
                parts.append(
                    _delta_encode(self._canonical_column(s, name).view("<u8"))
                )
                parts.append(np.packbits(
                    self._present_column(s, name).astype(bool)
                ).tobytes())
        body = b"".join(parts)
        magic = WIRE_V3_MAGIC if version == 3 else WIRE_V2_MAGIC
        return (magic + struct.pack("<I", len(body))
                + zlib.compress(body, 6))

    @staticmethod
    def wire_version(buf: bytes) -> int:
        """The wire version a payload's magic declares (without decoding);
        raises :class:`WireFormatError` on an unknown magic."""
        magic = bytes(buf[:4])
        if magic == WIRE_V1_MAGIC:
            return 1
        if magic == WIRE_V2_MAGIC:
            return 2
        if magic == WIRE_V3_MAGIC:
            return 3
        raise WireFormatError(
            f"not a StepDelta wire buffer (bad magic {magic!r})"
        )

    @staticmethod
    def _validated_header(head: bytes, version: int = 2) -> dict:
        try:
            header = json.loads(head.decode())
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise WireFormatError(f"corrupt StepDelta header: {e}") from e
        if not isinstance(header, dict) or not isinstance(
            header.get("stages"), list
        ):
            raise WireFormatError("StepDelta header is not an object with stages")
        try:
            if not isinstance(header["host"], str):
                raise TypeError("host is not a string")
            int(header["seq"])
            int(header.get("boot", 0))
        except (KeyError, TypeError, ValueError) as e:
            raise WireFormatError(
                f"StepDelta header missing/malformed host/seq/boot: {e}"
            ) from e
        if version == 3:
            causes = header.get("causes", [])
            if not isinstance(causes, list) or not all(
                isinstance(c, dict) for c in causes
            ):
                raise WireFormatError(
                    "StepDelta v3 causes is not a list of objects"
                )
            if len(causes) > _MAX_WIRE_CAUSES:
                raise WireFormatError(
                    f"implausible attributed-cause count {len(causes)}"
                )
        elif "causes" in header:
            raise WireFormatError(
                f"StepDelta v{version} header carries a causes key "
                "(attribution requires wire version 3)"
            )
        for sh in header["stages"]:
            if not isinstance(sh, dict):
                raise WireFormatError("StepDelta stage header is not an object")
            try:
                if not isinstance(sh["stage_id"], str):
                    raise TypeError("stage_id is not a string")
                n = int(sh["n"])
                task_ids, nodes = sh["task_ids"], sh["nodes"]
                columns = sh["columns"]
                if not isinstance(task_ids, list) or not isinstance(nodes, list):
                    raise TypeError("task_ids/nodes are not lists")
                if not isinstance(columns, list) or not all(
                    isinstance(c, str) for c in columns
                ):
                    raise TypeError("columns is not a list of strings")
            except (KeyError, TypeError, ValueError) as e:
                raise WireFormatError(f"malformed stage header: {e}") from e
            if not 0 <= n <= _MAX_ROWS_PER_STAGE:
                raise WireFormatError(f"implausible stage row count {n}")
            if len(task_ids) != n or len(nodes) != n:
                raise WireFormatError(
                    f"stage {sh['stage_id']!r} declares n={n} but has "
                    f"{len(task_ids)} task_ids / {len(nodes)} nodes"
                )
        return header

    @classmethod
    def from_bytes(cls, buf: bytes) -> "StepDelta":
        """Decode a v1, v2, or v3 payload (dispatched on the magic).
        Every header-declared length is validated against the actual
        remaining bytes before any buffer view is taken; a truncated,
        over-long, or corrupt frame raises :class:`WireFormatError`.
        A v3 payload additionally yields the header's attribution block
        as ``causes`` (wire-form dicts, verbatim)."""
        buf = bytes(buf)
        if len(buf) < 8:
            raise WireFormatError(
                f"StepDelta payload too short ({len(buf)} bytes)"
            )
        version = cls.wire_version(buf)
        (length,) = struct.unpack_from("<I", buf, 4)
        if version >= 2:
            if length > _MAX_BODY_BYTES:
                raise WireFormatError(
                    f"StepDelta v{version} declares an implausible "
                    f"{length}-byte body"
                )
            try:
                z = zlib.decompressobj()
                # max_length caps allocation at the declared size *before*
                # inflating: a lying header cannot decompress-bomb us.
                body = z.decompress(buf[8:], length + 1)
            except zlib.error as e:
                raise WireFormatError(
                    f"corrupt StepDelta v{version} compression stream: {e}"
                ) from e
            if len(body) != length:
                raise WireFormatError(
                    f"StepDelta v{version} body is {len(body)}+ bytes but "
                    f"the frame declares {length}"
                )
            if not z.eof or z.unused_data:
                raise WireFormatError(
                    f"StepDelta v{version} compression stream is truncated "
                    "or has trailing bytes"
                )
            _need(len(body), 0, 4, "v2 header length")
            (hlen,) = struct.unpack_from("<I", body, 0)
            _need(len(body), 4, hlen, "v2 header")
            header = cls._validated_header(body[4 : 4 + hlen], version)
            off = 4 + hlen
            stages = []
            for sh in header["stages"]:
                n = int(sh["n"])
                sid = sh["stage_id"]
                starts, off = _delta_decode(body, off, n, "<u8",
                                            f"stage {sid!r} starts")
                ends, off = _delta_decode(body, off, n, "<u8",
                                          f"stage {sid!r} ends")
                loc, off = _delta_decode(body, off, n, "<u2",
                                         f"stage {sid!r} locality")
                columns: dict[str, np.ndarray] = {}
                present: dict[str, np.ndarray] = {}
                nmask = (n + 7) // 8
                for name in sh["columns"]:
                    what = f"stage {sid!r} column {name!r}"
                    col, off = _delta_decode(body, off, n, "<u8", what)
                    columns[name] = col.view("<f8").astype(np.float64)
                    _need(len(body), off, nmask, f"{what} present mask")
                    present[name] = np.unpackbits(
                        np.frombuffer(body, np.uint8, nmask, off), count=n
                    ).astype(bool)
                    off += nmask
                stages.append(StageDelta(
                    sid, list(sh["task_ids"]), list(sh["nodes"]),
                    starts.view("<f8").astype(np.float64),
                    ends.view("<f8").astype(np.float64),
                    loc.view("<i2").astype(np.int16),
                    columns, present,
                ))
            if off != len(body):
                raise WireFormatError(
                    f"StepDelta v{version} body has "
                    f"{len(body) - off} trailing bytes"
                )
            return cls(header["host"], int(header["seq"]), stages,
                       boot=int(header.get("boot", 0)),
                       causes=list(header.get("causes", [])))

        hlen = length
        _need(len(buf), 8, hlen, "v1 header")
        header = cls._validated_header(buf[8 : 8 + hlen], version)
        off = 8 + hlen
        stages = []
        for sh in header["stages"]:
            n = int(sh["n"])
            sid = sh["stage_id"]

            def take(dtype, what):
                nonlocal off
                itemsize = np.dtype(dtype).itemsize
                _need(len(buf), off, n * itemsize,
                      f"stage {sid!r} {what}")
                arr = np.frombuffer(buf, dtype=dtype, count=n, offset=off)
                off += arr.nbytes
                return arr

            starts = take("<f8", "starts").astype(np.float64)
            ends = take("<f8", "ends").astype(np.float64)
            locality = take("<i2", "locality").astype(np.int16)
            columns = {}
            present = {}
            for name in sh["columns"]:
                columns[name] = take("<f8", f"column {name!r}").astype(np.float64)
                present[name] = take("u1", f"column {name!r} mask").astype(bool)
            stages.append(StageDelta(
                sid, list(sh["task_ids"]), list(sh["nodes"]),
                starts, ends, locality, columns, present,
            ))
        if off != len(buf):
            raise WireFormatError(
                f"StepDelta v1 payload has {len(buf) - off} trailing bytes"
            )
        return cls(header["host"], int(header["seq"]), stages,
                   boot=int(header.get("boot", 0)))


#: Inner payload count cap per forwarded envelope — far above any real
#: forward batch, and it bounds what a corrupt header can allocate.
_MAX_FWD_PAYLOADS = 1 << 16

#: Envelope-in-envelope nesting a consumer will unwrap before declaring
#: the frame hostile.  A well-formed tree re-wraps at each hop (inner
#: payloads are always leaf StepDeltas), so real depth is 1; the cap only
#: bounds adversarial recursion.
MAX_FORWARD_DEPTH = 8


@dataclass
class ForwardedDelta:
    """A tree aggregator's pre-merged forwarded frame (wire magic
    ``BRDF``): the envelope around the inner :class:`StepDelta` payloads
    it accepted from its sub-fleet since its last forward.

    The envelope is *re-stamped* with the aggregator's own identity —
    ``host`` is the aggregator's fleet-unique name, ``(boot, seq)`` its
    incarnation stamp and per-forward counter — so the upstream
    consumer's ``(boot, seq)`` watermark dedups envelope redelivery
    exactly as it dedups host deltas.  The inner payloads ride through
    **verbatim** (the bytes the aggregator itself ingested, each keeping
    its original producer stamp): the root therefore dedups at *both*
    granularities, and a failed-over aggregator that re-forwards payloads
    an earlier incarnation already delivered produces only inner-level
    duplicate drops, never duplicate rows.  That per-payload exactness is
    what makes depth-2 aggregation byte-identical to the star topology.

    Wire layout (normative spec in ``docs/wire_format.md``)::

        "BRDF" | u32 header length | JSON header | inner payloads, concatenated

    with header ``{host, boot, seq, sizes: [len, ...]}``; every declared
    size is validated against the remaining bytes before any slice is
    taken, so a truncated or lying frame raises :class:`WireFormatError`.
    """

    host: str
    seq: int
    payloads: list[bytes]
    boot: int = 0

    @staticmethod
    def is_forwarded(buf) -> bool:
        """Cheap magic check (no decoding)."""
        return bytes(buf[:4]) == WIRE_FWD_MAGIC

    def to_bytes(self) -> bytes:
        head = json.dumps(
            {"host": self.host, "seq": self.seq, "boot": self.boot,
             "sizes": [len(p) for p in self.payloads]},
            separators=(",", ":"),
        ).encode()
        return b"".join(
            [WIRE_FWD_MAGIC, struct.pack("<I", len(head)), head,
             *map(bytes, self.payloads)]
        )

    @classmethod
    def from_bytes(cls, buf) -> "ForwardedDelta":
        buf = bytes(buf)
        if len(buf) < 8 or buf[:4] != WIRE_FWD_MAGIC:
            raise WireFormatError(
                f"not a ForwardedDelta wire buffer (magic {bytes(buf[:4])!r})"
            )
        (hlen,) = struct.unpack_from("<I", buf, 4)
        _need(len(buf), 8, hlen, "forwarded header")
        try:
            header = json.loads(buf[8 : 8 + hlen].decode())
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise WireFormatError(f"corrupt ForwardedDelta header: {e}") from e
        try:
            host = header["host"]
            if not isinstance(host, str):
                raise TypeError("host is not a string")
            seq = int(header["seq"])
            boot = int(header.get("boot", 0))
            sizes = header["sizes"]
            if not isinstance(sizes, list) or not all(
                isinstance(s, int) and s >= 0 for s in sizes
            ):
                raise TypeError("sizes is not a list of non-negative ints")
        except (KeyError, TypeError, ValueError) as e:
            raise WireFormatError(
                f"ForwardedDelta header missing/malformed fields: {e}"
            ) from e
        if len(sizes) > _MAX_FWD_PAYLOADS:
            raise WireFormatError(
                f"implausible forwarded payload count {len(sizes)}"
            )
        off = 8 + hlen
        payloads: list[bytes] = []
        for i, size in enumerate(sizes):
            _need(len(buf), off, size, f"forwarded payload {i}")
            payloads.append(buf[off : off + size])
            off += size
        if off != len(buf):
            raise WireFormatError(
                f"ForwardedDelta frame has {len(buf) - off} trailing bytes"
            )
        return cls(host, seq, payloads, boot=boot)


@dataclass
class StepScope:
    """Mutable accumulator for one step on one host."""

    node: str
    step: int
    start: float
    clock: object
    phases: dict[str, float] = field(default_factory=dict)
    counters: dict[str, float] = field(default_factory=dict)
    locality: int = 0
    end: float | None = None

    @contextmanager
    def phase(self, name: str):
        t0 = self.clock()
        try:
            yield
        finally:
            self.phases[name] = self.phases.get(name, 0.0) + (self.clock() - t0)

    def add(self, counter: str, value: float) -> None:
        self.counters[counter] = self.counters.get(counter, 0.0) + value

    def set_locality(self, locality: int) -> None:
        self.locality = locality


class StepTelemetry:
    """Per-host step-record emitter.

    Steps ingest straight into a columnar
    :class:`~repro.core.frame.TraceStore` (``self.trace``) — no per-step
    dataclass materialization on the hot path; ``trace`` still supports the
    full Trace API (``stages()``/``stage()``/``dump_jsonl``) and stages
    expose a ``TaskRecord`` view for compatibility.

    Usage::

        telem = StepTelemetry(node="host3", timeline=tl)
        with telem.step(i) as s:
            with s.phase("data_load"): batch = next(it)
            s.add("read_bytes", batch.nbytes)
            with s.phase("h2d"): batch = jax.device_put(batch)
            with s.phase("compute"): state, loss = train_step(state, batch)
        trace = telem.trace

    Streaming mode (``streaming=True``) additionally mirrors every emitted
    row into ``self.live_window`` — a
    :class:`~repro.core.window.SlidingStageWindow` holding the last
    ``window`` steps (override with ``stream_max_rows``/``stream_span``)
    with running aggregates, so an analyzer can run *inside* the loop at
    every step for O(changed rows) instead of resealing the stage::

        telem = StepTelemetry("host3", timeline=tl, streaming=True)
        stream = RootCauseStream(BigRootsAnalyzer(JAX_FEATURES, timelines=tl),
                                 telem.live_window)
        with telem.step(i) as s: ...
        for cause in stream.step():  # newly confirmed causes, live
            ...

    Wire mode (``wire=True``) buffers each emitted row until
    :meth:`drain_delta` cuts a columnar :class:`StepDelta` — the export
    surface a sharded fleet ships to the launcher's
    :class:`~repro.serve.FleetAggregator` for merged, fleet-wide diagnosis
    (``delta.to_bytes()`` / ``StepDelta.from_bytes`` for cross-process
    transport; pass the object directly in-process).
    """

    # phase name → TIME feature name in the JAX schema
    _PHASE_FEATURES = {
        "data_load": "data_load_time",
        "h2d": "h2d_time",
        "d2h": "d2h_time",
        "ckpt": "ckpt_time",
    }
    _RESOURCE_METRICS = ("cpu", "disk", "network")

    def __init__(
        self,
        node: str,
        timeline: ResourceTimeline | None = None,
        window: int = 1,
        clock=time.time,
        gc_timer: GcTimer | None = None,
        schema: FeatureSchema | None = None,
        streaming: bool = False,
        stream_max_rows: int | None = None,
        stream_span: float | None = None,
        stream_quantile: float = 0.9,
        wire: bool = False,
        wire_pending_cap: int = 65536,
        boot: int | None = None,
    ) -> None:
        self.node = node
        self.timeline = timeline
        self.window = max(int(window), 1)
        self.clock = clock
        self.gc_timer = gc_timer
        self.schema = schema or JAX_FEATURES
        self.trace = TraceStore(self.schema)
        self.live_window: SlidingStageWindow | None = None
        if streaming:
            self.live_window = SlidingStageWindow(
                f"{node}/live", self.schema,
                span=stream_span,
                max_rows=(stream_max_rows if stream_max_rows is not None
                          else self.window),
                quantile=stream_quantile,
            )
        # Wire mode: additionally buffer each emitted row until the next
        # drain_delta() — the sharded-fleet export surface.  ``boot``
        # stamps this producer incarnation so a consumer can tell a
        # restarted host (new boot) from a redelivered delta (same boot).
        # The buffer is bounded (``wire_pending_cap`` rows): if nobody
        # drains — a stalled launcher, or wire=True wired up without a
        # consumer — the oldest rows are dropped (``wire_overflow_drops``)
        # with a one-time warning instead of leaking an always-on loop's
        # memory.
        self.wire = wire
        self.wire_pending_cap = max(int(wire_pending_cap), 1)
        self.wire_overflow_drops = 0
        # ``boot`` defaults to the wall nanosecond stamp; deterministic
        # harnesses (repro.anomaly.scenario) inject one so a replay is
        # byte-identical.
        self.boot = time.time_ns() if boot is None else int(boot)
        self._pending: dict[str, list[tuple]] = {}
        self._delta_seq = 0
        self._overflow_warned = False

    def stage_id_for(self, step: int) -> str:
        """Stage = window of `window` consecutive steps (peer pooling)."""
        return f"steps_{(step // self.window) * self.window:06d}"

    @contextmanager
    def step(self, step: int):
        scope = StepScope(node=self.node, step=step, start=self.clock(), clock=self.clock)
        if self.gc_timer is not None:
            self.gc_timer.take()  # reset accumulator at step start
        try:
            yield scope
        finally:
            scope.end = self.clock()
            self._emit(scope)

    # -- record construction ----------------------------------------------------
    def _emit(self, scope: StepScope) -> None:
        features: dict[str, float] = {}
        for phase, feat in self._PHASE_FEATURES.items():
            if phase in scope.phases:
                features[feat] = scope.phases[phase]
        if self.gc_timer is not None:
            features["gc_time"] = self.gc_timer.take()
        features.update(scope.counters)

        # Resource features: Eq. 1-3 window means over the task interval.
        if self.timeline is not None:
            for metric in self._RESOURCE_METRICS:
                val = self.timeline.window_mean(self.node, metric, scope.start, scope.end)
                if val is not None:
                    features[metric] = val

        task_id = f"{self.node}/step{scope.step:06d}"
        self.trace.add_row(
            task_id=task_id,
            stage_id=self.stage_id_for(scope.step),
            node=self.node,
            start=scope.start,
            end=scope.end,
            locality=scope.locality,
            features=features,
        )
        if self.live_window is not None:
            self.live_window.add_row(
                task_id, self.node, scope.start, scope.end,
                scope.locality, features,
            )
            self.live_window.advance(scope.end)
        if self.wire:
            stage_id = self.stage_id_for(scope.step)
            self._pending.setdefault(stage_id, []).append(
                (task_id, self.node, scope.start, scope.end,
                 scope.locality, features)
            )
            if self.pending_rows > self.wire_pending_cap:
                # Nobody is draining: shed the oldest row (stages are
                # created in step order, so the first stage's head is the
                # oldest) and say so once.
                first = next(iter(self._pending))
                rows = self._pending[first]
                rows.pop(0)
                if not rows:
                    del self._pending[first]
                self.wire_overflow_drops += 1
                if not self._overflow_warned:
                    self._overflow_warned = True
                    import warnings

                    warnings.warn(
                        f"StepTelemetry({self.node!r}) wire buffer exceeded "
                        f"{self.wire_pending_cap} rows with no drain_delta() "
                        "consumer; dropping oldest rows",
                        RuntimeWarning,
                        stacklevel=3,
                    )

    # -- wire export (sharded fleet → launcher) -----------------------------
    @property
    def pending_rows(self) -> int:
        return sum(len(rows) for rows in self._pending.values())

    def drain_delta(self) -> StepDelta:
        """Cut a :class:`StepDelta` from the rows emitted since the last
        drain (requires ``wire=True``) and clear the buffer.  Feature dicts
        are columnarized per stage over the union of names seen in the
        batch, with a ``present`` mask so sparse rows round-trip exactly.
        An empty delta (no steps since last drain) is legal and cheap."""
        if not self.wire:
            raise RuntimeError("StepTelemetry(wire=True) required to drain deltas")
        stages: list[StageDelta] = []
        for stage_id, rows in self._pending.items():
            m = len(rows)
            names = sorted({nm for *_ , feats in rows for nm in feats})
            columns = {nm: np.zeros(m, dtype=np.float64) for nm in names}
            present = {nm: np.zeros(m, dtype=bool) for nm in names}
            starts = np.empty(m, dtype=np.float64)
            ends = np.empty(m, dtype=np.float64)
            locality = np.zeros(m, dtype=np.int16)
            task_ids: list[str] = []
            nodes: list[str] = []
            for i, (tid, node, t0, t1, loc, feats) in enumerate(rows):
                task_ids.append(tid)
                nodes.append(node)
                starts[i], ends[i], locality[i] = t0, t1, loc
                for nm, val in feats.items():
                    columns[nm][i] = float(val)
                    present[nm][i] = True
            stages.append(StageDelta(stage_id, task_ids, nodes, starts, ends,
                                     locality, columns, present))
        self._pending = {}
        self._delta_seq += 1
        return StepDelta(self.node, self._delta_seq, stages, boot=self.boot)

    # -- merging (multi-host traces are concatenated by the launcher) -----------
    def merge_into(self, trace) -> None:
        """Append this host's records into ``trace``.

        A :class:`~repro.core.frame.TraceStore` target takes the columnar
        merge path (per-stage block concatenation — no TaskRecord
        materialization); anything else falls back to the dataclass loop.
        """
        if isinstance(trace, TraceStore):
            trace.merge(self.trace)
            return
        for stage in self.trace.stages():
            for task in stage.tasks:
                trace.add_task(task)
