"""Telemetry: system samplers (Eq. 1-3), resource timelines, step events.

This is the data-acquisition substrate under BigRoots: the Spark-log +
mpstat/iostat/sar layer of the paper, re-homed onto an SPMD training host
(DESIGN.md §2 mapping table).
"""
from .events import (
    ForwardedDelta,
    GcTimer,
    StageDelta,
    StepDelta,
    StepTelemetry,
    WireFormatError,
)
from .sampler import SystemSampler, read_cpu_sample, read_disk_sample, read_net_sample
from .timeline import ResourceTimeline, TimelineCursor
from .transport import DeltaClient, DeltaServer, Endpoint, RingSender, ShmRing

__all__ = [
    "DeltaClient",
    "DeltaServer",
    "Endpoint",
    "ForwardedDelta",
    "GcTimer",
    "ResourceTimeline",
    "RingSender",
    "ShmRing",
    "StageDelta",
    "StepDelta",
    "StepTelemetry",
    "TimelineCursor",
    "SystemSampler",
    "WireFormatError",
    "read_cpu_sample",
    "read_disk_sample",
    "read_net_sample",
]
