"""GLM4-9B [hf:THUDM/glm-4-9b] — dense, RoPE, extreme GQA (kv=2)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=151552,
    qkv_bias=True,          # GLM uses qkv bias
)
