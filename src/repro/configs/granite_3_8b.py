"""Granite-3.0-8B [hf:ibm-granite/granite-3.0 family] — dense, GQA kv=8."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab=49155,
    tie_embeddings=True,    # granite-3 ties embeddings
)
