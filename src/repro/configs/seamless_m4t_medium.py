"""SeamlessM4T-medium [arXiv:2308.11596] — audio enc-dec backbone.

Modality frontend is a STUB: ``input_specs()`` supplies precomputed frame
embeddings [B, T_enc, d] (T_enc = seq_len/4, DESIGN.md §4); 12 encoder +
12 decoder layers at the paper's listed geometry (12L d=1024 16H kv=16
d_ff=4096 vocab=256206).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,            # decoder depth
    enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    frontend="frame_embed",
)
