"""OLMoE-1B-7B [arXiv:2409.02060] — MoE 64 experts, top-8, expert d_ff=1024."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,              # per-expert hidden
    vocab=50304,
    moe_experts=64,
    moe_top_k=8,
    moe_d_ff=1024,
)
