"""Jamba-v0.1-52B [arXiv:2403.19887] — hybrid Mamba+attention 1:7, MoE 16e top-2.

Pattern period 8: attention at layer index 4 of each period (attn_offset=4),
Mamba elsewhere; MoE every other layer (odd indices).  Mamba sub-config per
the Jamba paper: d_state 16, expand 2, conv 4 (SSD-form heads at head_dim 64
— TPU adaptation noted in DESIGN.md).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    moe_experts=16,
    moe_top_k=2,
    moe_d_ff=14336,
    moe_period=2,
    moe_offset=1,
    attn_period=8,
    attn_offset=4,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=256,
)
