"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B] — dense, MHA (kv=32), qkv bias."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    qkv_bias=True,          # qwen1.5 architecture
    rope_theta=1_000_000.0,  # qwen long-context base
)
