"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base] —
MoE 32 experts, top-8, expert d_ff=512, every layer MoE."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,               # per-expert hidden
    vocab=49155,
    moe_experts=32,
    moe_top_k=8,
    moe_d_ff=512,
    tie_embeddings=True,
)
