"""Mamba2-130M [arXiv:2405.21060] — attention-free SSD (state-space duality).

d_inner = 2·768 = 1536, head_dim 64 → 24 SSD heads, d_state 128, chunk 256.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,              # attention-free
    d_ff=0,                 # no FFN blocks in mamba2
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
)
