"""InternVL2-26B [arXiv:2404.16821] — VLM: InternViT frontend (STUB) +
InternLM2-20B language backbone (48L d=6144 48H kv=8 d_ff=16384 vocab=92553).

The ViT frontend is a STUB per the brief: ``input_specs()`` supplies 1024
precomputed patch embeddings [B, 1024, d] concatenated ahead of the text
tokens (DESIGN.md §4).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    frontend="patch_embed",
    frontend_tokens=1024,
)
