"""Architecture registry: the 10 assigned configs + input-shape sets.

Every (arch × shape) cell of the dry-run matrix is defined here; shapes are
the LM-family set (train_4k / prefill_32k / decode_32k / long_500k) with the
sub-quadratic gate on long_500k (DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass
from importlib import import_module

from ..models.config import ModelConfig

ARCH_IDS = [
    "codeqwen1_5_7b",
    "glm4_9b",
    "granite_3_8b",
    "granite_8b",
    "seamless_m4t_medium",
    "granite_moe_1b_a400m",
    "olmoe_1b_7b",
    "mamba2_130m",
    "jamba_v0_1_52b",
    "internvl2_26b",
]

# CLI-facing ids (dashes) → module names (underscores)
def _norm(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    mod = import_module(f"repro.configs.{_norm(arch)}")
    return mod.CONFIG.validate()


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# Archs with sub-quadratic sequence mixing (may run long_500k).
SUBQUADRATIC = {"mamba2_130m", "jamba_v0_1_52b"}


def shapes_for(arch: str) -> list[ShapeSpec]:
    arch = _norm(arch)
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if arch in SUBQUADRATIC:
        out.append(SHAPES["long_500k"])
    return out


def cells() -> list[tuple[str, ShapeSpec]]:
    """All runnable (arch, shape) dry-run cells; skipped cells are the
    long_500k rows of pure full-attention archs (DESIGN.md §4)."""
    return [(a, s) for a in ARCH_IDS for s in shapes_for(a)]


def skipped_cells() -> list[tuple[str, str, str]]:
    return [
        (a, "long_500k", "pure full-attention arch: 500k dense decode is the "
                          "quadratic regime the shape excludes")
        for a in ARCH_IDS if a not in SUBQUADRATIC
    ]
