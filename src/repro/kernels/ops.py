"""Public jit'd wrappers over the Pallas kernels.

These are the entry points models call when ``cfg.attention_impl="pallas"``
etc.  On this container (CPU) kernels run with ``interpret=True``; on a real
TPU the same call sites compile the Mosaic kernels.  `INTERPRET` flips the
default per-platform.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention
from .flash_attention import flash_attention
from .moe_gmm import grouped_matmul
from .ssd_scan import ssd_intra_chunk

INTERPRET = jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# attention entry points in model layout ([B, S, H, D])
# ---------------------------------------------------------------------------
def mha_flash(q, k, v, *, causal=True, block_q=128, block_k=128,
              interpret: bool | None = None):
    """q [B,S,H,D]; k/v [B,S,KV,D] → [B,S,H,D] (GQA folded into the kernel)."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    n_rep = H // KV
    q2 = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    k2 = k.transpose(0, 2, 1, 3).reshape(B * KV, k.shape[1], D)
    v2 = v.transpose(0, 2, 1, 3).reshape(B * KV, v.shape[1], D)
    out = flash_attention(
        q2, k2, v2, causal=causal, block_q=block_q, block_k=block_k,
        n_rep=n_rep, interpret=INTERPRET if interpret is None else interpret,
    )
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)


def mha_decode(q, k_cache, v_cache, cache_len, *, block_k=512,
               interpret: bool | None = None):
    """q [B,1,H,D]; caches [B,S,KV,D] → [B,1,H,D]."""
    B, _, H, D = q.shape
    KV = k_cache.shape[2]
    n_rep = H // KV
    q2 = q[:, 0].transpose(0, 1, 2).reshape(B * H, D)
    k2 = k_cache.transpose(0, 2, 1, 3).reshape(B * KV, k_cache.shape[1], D)
    v2 = v_cache.transpose(0, 2, 1, 3).reshape(B * KV, v_cache.shape[1], D)
    out = decode_attention(
        q2, k2, v2, cache_len, block_k=block_k, n_rep=n_rep,
        interpret=INTERPRET if interpret is None else interpret,
    )
    return out.reshape(B, 1, H, D)


# ---------------------------------------------------------------------------
# SSD: full chunked layer (kernel intra-chunk + XLA inter-chunk recurrence)
# ---------------------------------------------------------------------------
def ssd_chunked_pallas(x, dt, A, Bm, Cm, chunk: int, h0=None,
                       interpret: bool | None = None):
    """Same contract as models.ssd.ssd_chunked, Pallas intra-chunk path.
    x [B,S,H,P]; dt [B,S,H]; A [H]; Bm/Cm [B,S,G,N]."""
    Bb, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, S)
    assert S % Q == 0
    Nc = S // Q
    interp = INTERPRET if interpret is None else interpret

    # [B,S,H,*] → [B,H,Nc,Q,*]
    xc = x.reshape(Bb, Nc, Q, H, P).transpose(0, 3, 1, 2, 4)
    dtc = dt.reshape(Bb, Nc, Q, H).transpose(0, 3, 1, 2)
    Bc = jnp.repeat(Bm, rep, axis=2).reshape(Bb, Nc, Q, H, N).transpose(0, 3, 1, 2, 4)
    Cc = jnp.repeat(Cm, rep, axis=2).reshape(Bb, Nc, Q, H, N).transpose(0, 3, 1, 2, 4)

    y_intra, s_c, seg = ssd_intra_chunk(xc, dtc, A, Bc, Cc, interpret=interp)

    # inter-chunk recurrence in XLA (cheap): h advances chunk by chunk
    chunk_sum = seg[..., -1]                                # [B,H,Nc]
    chunk_decay = jnp.exp(chunk_sum)
    h_init = (
        jnp.zeros((Bb, H, P, N), jnp.float32) if h0 is None
        else h0.astype(jnp.float32)
    )

    def step(h, inp):
        dec, s = inp                                        # [B,H], [B,H,N,P]
        h_new = h * dec[:, :, None, None] + s.transpose(0, 1, 3, 2)
        return h_new, h

    h_final, h_before = jax.lax.scan(
        step, h_init,
        (chunk_decay.transpose(2, 0, 1), s_c.transpose(2, 0, 1, 3, 4)),
    )
    h_before = h_before.transpose(1, 2, 0, 3, 4)            # [B,H,Nc,P,N]

    in_decay = jnp.exp(seg)                                 # [B,H,Nc,Q]
    y_inter = jnp.einsum(
        "bhcqn,bhcpn->bhcqp", Cc * in_decay[..., None], h_before
    )
    y = (y_intra.astype(jnp.float32) + y_inter)             # [B,H,Nc,Q,P]
    y = y.transpose(0, 2, 3, 1, 4).reshape(Bb, S, H, P)
    return y.astype(x.dtype), h_final


# ---------------------------------------------------------------------------
# MoE: sorted+padded grouped FFN (kernel path of models.moe)
# ---------------------------------------------------------------------------
def moe_gmm_ffn(xs, group_sizes, w_gate, w_up, w_down, *, capacity_tile=128,
                interpret: bool | None = None):
    """xs [T, d] tokens sorted by expert; group_sizes [E].
    Returns [T, d] expert-FFN outputs (same order).  Pads each group to the
    capacity tile, runs three grouped matmuls, then unpads."""
    interp = INTERPRET if interpret is None else interpret
    T, d = xs.shape
    E = w_gate.shape[0]
    cap = max(capacity_tile, ((T + E - 1) // E + capacity_tile - 1)
              // capacity_tile * capacity_tile)
    # scatter sorted tokens into [E, cap, d] (rows past group size stay zero)
    starts = jnp.concatenate([jnp.zeros((1,), group_sizes.dtype),
                              jnp.cumsum(group_sizes)[:-1]])
    token_expert = jnp.repeat(jnp.arange(E), 1)  # placeholder, computed below
    idx = jnp.arange(T)
    expert_of = jnp.searchsorted(jnp.cumsum(group_sizes), idx, side="right")
    slot = idx - starts[expert_of]
    ok = slot < cap
    xpad = jnp.zeros((E, cap, d), xs.dtype)
    xpad = xpad.at[expert_of, jnp.where(ok, slot, 0)].set(
        jnp.where(ok[:, None], xs, 0.0)
    )
    g = grouped_matmul(xpad, w_gate, interpret=interp)
    u = grouped_matmul(xpad, w_up, interpret=interp)
    h = jax.nn.silu(g) * u
    y = grouped_matmul(h, w_down, interpret=interp)         # [E, cap, d]
    out = y[expert_of, jnp.where(ok, slot, 0)]
    return jnp.where(ok[:, None], out, 0.0)
