"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, n_rep=1):
    """q [BH, Sq, D]; k/v [BKV, Sk, D] → [BH, Sq, D]."""
    BH, Sq, D = q.shape
    k = jnp.repeat(k, n_rep, axis=0)
    v = jnp.repeat(v, n_rep, axis=0)
    scale = 1.0 / math.sqrt(D)
    logits = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    if causal:
        Sk = k.shape[1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        logits = jnp.where(mask[None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(q.dtype), v)


def decode_attention_ref(q, k, v, cache_len, *, n_rep=1):
    """q [BH, D]; k/v [BKV, S, D]; positions > cache_len masked."""
    k = jnp.repeat(k, n_rep, axis=0)
    v = jnp.repeat(v, n_rep, axis=0)
    D = q.shape[-1]
    S = k.shape[1]
    logits = jnp.einsum("bd,bkd->bk", q, k).astype(jnp.float32) / math.sqrt(D)
    valid = jnp.arange(S)[None, :] <= cache_len
    logits = jnp.where(valid, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bk,bkd->bd", p.astype(q.dtype), v)


def ssd_intra_chunk_ref(x, dt, A, B_, C):
    """Chunked-layout oracle.  x [B,H,Nc,Q,P], dt [B,H,Nc,Q], A [H],
    B_/C [B,H,Nc,Q,N] → (y_intra, state, seg) matching ssd_scan."""
    f32 = jnp.float32
    xf, dtf = x.astype(f32), dt.astype(f32)
    Bf, Cf = B_.astype(f32), C.astype(f32)
    a = dtf * A.astype(f32)[None, :, None, None]
    seg = jnp.cumsum(a, axis=-1)                            # [B,H,Nc,Q]
    Q = x.shape[3]
    decay = jnp.exp(seg[..., :, None] - seg[..., None, :])  # [B,H,Nc,Q,Q]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(mask, decay, 0.0)
    scores = jnp.einsum("bhcin,bhcjn->bhcij", Cf, Bf) * decay
    scores = scores * dtf[..., None, :]
    y = jnp.einsum("bhcij,bhcjp->bhcip", scores, xf)
    state_decay = jnp.exp(seg[..., -1:] - seg)              # [B,H,Nc,Q]
    xw = xf * (dtf * state_decay)[..., None]
    s = jnp.einsum("bhcjn,bhcjp->bhcnp", Bf, xw)
    return y.astype(x.dtype), s, seg


def grouped_matmul_ref(x, w):
    """x [E, Cap, d]; w [E, d, f] → [E, Cap, f]."""
    return jnp.einsum("ecd,edf->ecf", x, w).astype(x.dtype)
