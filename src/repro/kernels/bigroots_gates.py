"""Pallas TPU kernel: batched BigRoots Eq. 5 gate pipeline for fleet sweeps.

The §III-B gate algebra — the λq quantile gate, the inter-/intra-node
peer-mean gates (the paper's observations 1 & 2), the TIME significance
floor ``F > 0.2`` and the NUMERICAL stage-mean ≤ 0 guard — is a pure
elementwise pipeline over ``[rows, F]`` gate-space matrices.  One
always-on diagnosis step evaluates it per stage window; a *fleet sweep*
evaluates it for every stage window of every job on the cluster.  This
kernel batches that sweep: ``repro.core.fleet.pack_windows`` stacks the
straggler rows of many :class:`~repro.core.window.SlidingStageWindow`\\ s
(their gate-space ``v`` rows, gathered per-row node aggregates, running
``Σv`` and sketch quantiles) into padded ``[n_windows, max_rows, F]``
device arrays, and a single launch returns the fired-gate bits for the
whole fleet.

Inputs (all per packed batch; see :class:`repro.core.fleet.FleetGateBatch`):

==============  ===================  =========================================
``v``           ``[W, R, F]``        gate-space values of the packed rows
``peer_vsum``   ``[W, R, F]``        per-row node Σv (``node_vsums[code]``)
``inter_cnt``   ``[W, R, 1]``        ``n - count(node)`` per row
``intra_cnt``   ``[W, R, 1]``        ``count(node) - 1`` per row
``rowmask``     ``[W, R, 1]``        1.0 for real rows, 0.0 for padding
``vsum``        ``[W, 1, F]``        window running Σv
``q``           ``[W, 1, F]``        per-column λq thresholds (sketch/exact)
``numok``       ``[W, 1, F]``        NUMERICAL mean>0 guard (1.0 = pass)
``floor``       ``[1, 1, F]``        TIME floor per column (−inf elsewhere)
==============  ===================  =========================================

Output: ``gbits [W, R, F]`` int8 — 0 where no gate fired; else bit 0 set
when the inter-node observation fired and bit 1 for intra-node, matching
the analyzer's peer-group emission table.

Exactness: gate math runs in the input dtype.  The equivalence suite (and
the analyzer's ``backend="jax"|"pallas"`` dispatch) runs under
``jax.experimental.enable_x64`` so float64 comparisons are bit-identical
to the numpy reference path; on a real TPU the same kernel compiles in
float32 (Mosaic has no f64) — knife-edge λq rows may then differ, exactly
like the documented P² sketch tolerance.  Division by an empty peer
group's zero count produces NaN/±inf that the explicit ``cnt > 0`` masks
neutralize, mirroring the numpy path's ``isnan`` guards.

Validated in interpret mode on CPU (CI); compiled by Mosaic on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64
from jax.experimental import pallas as pl

import numpy as np

def _default_interpret() -> bool:
    """Interpret off-TPU.  Resolved lazily at the first eval_gates call —
    probing the backend at import time would initialize XLA for every
    importer of repro.kernels, even ones that never evaluate a gate."""
    return jax.default_backend() != "tpu"


def _gates_kernel(v_ref, pv_ref, icnt_ref, acnt_ref, m_ref, vs_ref, q_ref,
                  nok_ref, fl_ref, o_ref, *, peer_mean: float):
    v = v_ref[0]            # [Br, F]
    pv = pv_ref[0]          # [Br, F]
    icnt = icnt_ref[0]      # [Br, 1]
    acnt = acnt_ref[0]      # [Br, 1]
    mask = m_ref[0]         # [Br, 1]
    vsum = vs_ref[0]        # [1, F]
    q = q_ref[0]            # [1, F]
    numok = nok_ref[0]      # [1, F]
    floor = fl_ref[0]       # [1, F]

    # Peer means from the running aggregates (identical operand order to the
    # numpy path so float comparisons round the same way).
    inter = (vsum - pv) / icnt
    intra = (pv - v) / acnt
    gate_inter = (v > inter * peer_mean) & (icnt > 0.0)
    gate_intra = (v > intra * peer_mean) & (acnt > 0.0)
    fired = (
        (mask > 0.0)
        & (v > q)                       # λq quantile gate
        & (gate_inter | gate_intra)     # Eq. 5 peer-mean observations
        & (numok > 0.0)                 # NUMERICAL stage-mean ≤ 0 guard
        & (v > floor)                   # TIME significance floor
    )
    gbits = gate_inter.astype(jnp.int8) + 2 * gate_intra.astype(jnp.int8)
    o_ref[0] = jnp.where(fired, gbits, jnp.int8(0))


@functools.partial(
    jax.jit, static_argnames=("peer_mean", "block_r", "interpret")
)
def _gates_pallas(v, peer_vsum, inter_cnt, intra_cnt, rowmask, vsum, q,
                  numok, floor, *, peer_mean: float, block_r: int,
                  interpret: bool):
    W, R, F = v.shape
    n_rt = R // block_r
    kernel = functools.partial(_gates_kernel, peer_mean=peer_mean)
    row_spec = pl.BlockSpec((1, block_r, F), lambda w, r: (w, r, 0))
    cnt_spec = pl.BlockSpec((1, block_r, 1), lambda w, r: (w, r, 0))
    col_spec = pl.BlockSpec((1, 1, F), lambda w, r: (w, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=(W, n_rt),
        in_specs=[
            row_spec,                                       # v
            row_spec,                                       # peer_vsum
            cnt_spec,                                       # inter_cnt
            cnt_spec,                                       # intra_cnt
            cnt_spec,                                       # rowmask
            col_spec,                                       # vsum
            col_spec,                                       # q
            col_spec,                                       # numok
            pl.BlockSpec((1, 1, F), lambda w, r: (0, 0, 0)),  # floor
        ],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((W, R, F), jnp.int8),
        interpret=interpret,
    )(v, peer_vsum, inter_cnt, intra_cnt, rowmask, vsum, q, numok, floor)


@functools.partial(jax.jit, static_argnames=("peer_mean",))
def _gates_jnp(v, peer_vsum, inter_cnt, intra_cnt, rowmask, vsum, q, numok,
               floor, *, peer_mean: float):
    """Pure-jnp reference of the kernel (the XLA-fused fallback backend)."""
    inter = (vsum - peer_vsum) / inter_cnt
    intra = (peer_vsum - v) / intra_cnt
    gate_inter = (v > inter * peer_mean) & (inter_cnt > 0.0)
    gate_intra = (v > intra * peer_mean) & (intra_cnt > 0.0)
    fired = (
        (rowmask > 0.0) & (v > q) & (gate_inter | gate_intra)
        & (numok > 0.0) & (v > floor)
    )
    gbits = gate_inter.astype(jnp.int8) + 2 * gate_intra.astype(jnp.int8)
    return jnp.where(fired, gbits, jnp.int8(0))


def eval_gates(
    v: np.ndarray,
    peer_vsum: np.ndarray,
    inter_cnt: np.ndarray,
    intra_cnt: np.ndarray,
    rowmask: np.ndarray,
    vsum: np.ndarray,
    q: np.ndarray,
    numok: np.ndarray,
    floor: np.ndarray,
    *,
    peer_mean: float,
    backend: str = "pallas",
    block_r: int = 256,
    interpret: bool | None = None,
) -> np.ndarray:
    """Evaluate the Eq. 5 gate pipeline for a packed fleet batch.

    ``backend="pallas"`` launches the kernel (interpret mode off-TPU by
    default); ``backend="jax"`` runs the jit'd pure-jnp reference.  For
    BOTH backends rows are zero-padded to a ``block_r`` multiple: the
    kernel grid needs it, and the jnp path needs the shape *bucketing* —
    an always-on loop sees a drifting straggler count every step, and
    without padding each distinct count would retrace and recompile the
    jit cache (tens of ms) instead of hitting one entry per bucket.
    Padding is masked by construction (``rowmask`` padding is 0).  Runs
    under ``enable_x64`` so float64 batches stay float64 end to end.
    Returns ``gbits`` as a numpy int8 array of the unpadded shape.
    """
    if backend not in ("jax", "pallas"):
        raise ValueError(f"unknown gate backend: {backend!r}")
    W, R, F = v.shape
    block_r = max(8, min(int(block_r), _round_up(R, 8)))
    R_pad = _round_up(R, block_r)
    if R_pad != R:
        pad = ((0, 0), (0, R_pad - R), (0, 0))
        v = np.pad(v, pad)
        peer_vsum = np.pad(peer_vsum, pad)
        # Padded counts are 1 (not 0) so the kernel's divisions stay
        # finite noise-free; rowmask padding stays 0 and masks them.
        inter_cnt = np.pad(inter_cnt, pad, constant_values=1.0)
        intra_cnt = np.pad(intra_cnt, pad, constant_values=1.0)
        rowmask = np.pad(rowmask, pad)
    with enable_x64():
        args = (
            jnp.asarray(v), jnp.asarray(peer_vsum), jnp.asarray(inter_cnt),
            jnp.asarray(intra_cnt), jnp.asarray(rowmask), jnp.asarray(vsum),
            jnp.asarray(q), jnp.asarray(numok), jnp.asarray(floor),
        )
        if backend == "jax":
            out = _gates_jnp(*args, peer_mean=float(peer_mean))
        else:
            out = _gates_pallas(
                *args, peer_mean=float(peer_mean), block_r=block_r,
                interpret=(_default_interpret() if interpret is None
                           else bool(interpret)),
            )
        return np.asarray(out)[:, :R]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
