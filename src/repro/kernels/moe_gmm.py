"""Pallas TPU kernel: grouped matmul (MoE expert FFN over sorted tokens).

Tokens arrive sorted by expert and padded per-expert to a capacity multiple
of the token tile (the ops.py wrapper builds the [E, Cap, d] layout), so the
kernel is a batched tiled matmul: grid (expert, cap_tile, out_tile, k_tile)
with an f32 VMEM accumulator carried across the sequential k axis.  All tile
shapes are MXU-aligned (128 multiples where dims allow).

Padding rows are zero, so they produce zero outputs — the wrapper's scatter
back to token order drops them.  FLOP overhead vs. a true ragged GEMM is at
most one tile per expert.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                    # [Bt, Bk]
    w = w_ref[0]                    # [Bk, Bf]
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_t", "block_f", "block_k", "interpret")
)
def grouped_matmul(
    x: jax.Array,      # [E, Cap, d]  zero-padded per-expert token groups
    w: jax.Array,      # [E, d, f]
    *,
    block_t: int = 128,
    block_f: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    E, Cap, d = x.shape
    _, _, f = w.shape
    block_t = min(block_t, Cap)
    block_f = min(block_f, f)
    block_k = min(block_k, d)
    assert Cap % block_t == 0 and f % block_f == 0 and d % block_k == 0
    n_t, n_f, n_k = Cap // block_t, f // block_f, d // block_k

    kernel = functools.partial(_gmm_kernel, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(E, n_t, n_f, n_k),
        in_specs=[
            pl.BlockSpec((1, block_t, block_k), lambda e, ti, fi, ki: (e, ti, ki)),
            pl.BlockSpec((1, block_k, block_f), lambda e, ti, fi, ki: (e, ki, fi)),
        ],
        out_specs=pl.BlockSpec(
            (1, block_t, block_f), lambda e, ti, fi, ki: (e, ti, fi)
        ),
        out_shape=jax.ShapeDtypeStruct((E, Cap, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_t, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
