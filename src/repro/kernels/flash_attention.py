"""Pallas TPU kernel: fused causal/full GQA attention forward (flash).

TPU adaptation: Q/K/V stream through VMEM in MXU-aligned blocks
(block_q × head_dim, block_k × head_dim with 128-multiples); the online
softmax state (running max / denominator / accumulator) lives in VMEM
scratch and is carried across the sequential innermost grid dimension
(TPU grids execute the last axis in order, which replaces the GPU
warp-level loop of the original flash algorithm).

Layout: q [BH, Sq, D]; k/v [BKV, Sk, D] with GQA handled by the kernel's
index_map (query head bh reads kv head bh // n_rep — no materialized
repeat_kv).  Output [BH, Sq, D].
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,        # [1, Bq, D], [1, Bk, D], [1, Bk, D]
    o_ref,                      # [1, Bq, D]
    acc_ref, m_ref, l_ref,      # VMEM scratch: [Bq, D] f32, [Bq, 1] f32 ×2
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    n_kv_blocks: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                               # [Bq, Bk]
    if causal:
        qpos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0
        )
        kpos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1
        )
        logits = jnp.where(qpos >= kpos, logits, NEG_INF)

    m_prev = m_ref[...]                                     # [Bq, 1]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1, keepdims=True))
    p = jnp.exp(logits - m_new)                             # [Bq, Bk]
    corr = jnp.exp(m_prev - m_new)                          # [Bq, 1]
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "n_rep", "interpret"),
)
def flash_attention(
    q: jax.Array,            # [BH, Sq, D]
    k: jax.Array,            # [BKV, Sk, D]
    v: jax.Array,            # [BKV, Sk, D]
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    n_rep: int = 1,          # BH == BKV * n_rep (GQA)
    interpret: bool = False,
) -> jax.Array:
    BH, Sq, D = q.shape
    BKV, Sk, _ = k.shape
    assert BH == BKV * n_rep, (BH, BKV, n_rep)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    n_q, n_k = Sq // block_q, Sk // block_k
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        n_kv_blocks=n_k,
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, qi, ki: (b // n_rep, ki, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, qi, ki: (b // n_rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
