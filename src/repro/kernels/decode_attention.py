"""Pallas TPU kernel: flash-decode (split-K single-token attention).

One new query token attends to a long KV cache.  The cache sequence is
split across the innermost grid axis; each split computes partial softmax
statistics (max, denominator, weighted-value accumulator) over its KV span
in VMEM, and the cheap cross-split combine happens in the jitted wrapper
(O(n_splits · D) — negligible next to the O(S · D) streaming).

This is the TPU analog of GPU flash-decode: splits map to the sequential
grid rather than SMs, and the valid-length mask comes in through SMEM.

Layout: q [BH, D]; k/v [BKV, S, D]; cache_len scalar → out [BH, D].
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    len_ref,                     # SMEM [1, 1] int32: valid cache length
    q_ref, k_ref, v_ref,         # [1, D], [1, Bk, D], [1, Bk, D]
    m_ref, l_ref, acc_ref,       # outs per split: [1,1,1], [1,1,1], [1,1,D]
    *,
    scale: float,
    block_k: int,
):
    si = pl.program_id(1)
    q = q_ref[...]                                          # [1, D]
    k = k_ref[0]                                            # [Bk, D]
    v = v_ref[0]
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                               # [1, Bk]
    pos = si * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    valid = pos <= len_ref[0, 0]                            # decode token at index len
    logits = jnp.where(valid, logits, NEG_INF)
    m = logits.max(axis=-1, keepdims=True)                  # [1, 1]
    p = jnp.exp(logits - m)
    p = jnp.where(valid, p, 0.0)
    l = p.sum(axis=-1, keepdims=True)
    acc = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                        # [1, D]
    m_ref[0] = m
    l_ref[0] = l
    acc_ref[0] = acc


@functools.partial(
    jax.jit, static_argnames=("block_k", "n_rep", "interpret")
)
def decode_attention(
    q: jax.Array,            # [BH, D]
    k: jax.Array,            # [BKV, S, D]
    v: jax.Array,            # [BKV, S, D]
    cache_len: jax.Array,    # [] int32 — index of the current token
    *,
    block_k: int = 512,
    n_rep: int = 1,
    interpret: bool = False,
) -> jax.Array:
    BH, D = q.shape
    BKV, S, _ = k.shape
    assert BH == BKV * n_rep
    block_k = min(block_k, S)
    assert S % block_k == 0
    n_s = S // block_k
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k)
    len_arr = jnp.reshape(cache_len.astype(jnp.int32), (1, 1))
    m, l, acc = pl.pallas_call(
        kernel,
        grid=(BH, n_s),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, D), lambda b, si: (b, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, si: (b // n_rep, si, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, si: (b // n_rep, si, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1), lambda b, si: (b, si, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, si: (b, si, 0)),
            pl.BlockSpec((1, 1, D), lambda b, si: (b, si, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, n_s, 1), jnp.float32),
            jax.ShapeDtypeStruct((BH, n_s, 1), jnp.float32),
            jax.ShapeDtypeStruct((BH, n_s, D), jnp.float32),
        ],
        interpret=interpret,
    )(len_arr, q, k, v)

    # cross-split combine (tiny): renormalize partial softmax statistics
    m_star = m.max(axis=1, keepdims=True)                   # [BH, 1, 1]
    w = jnp.exp(m - m_star)                                 # [BH, n_s, 1]
    out = (acc * w).sum(axis=1) / jnp.maximum((l * w).sum(axis=1), 1e-30)
    return out.astype(q.dtype)
