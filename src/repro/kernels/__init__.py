"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships three layers: ``<name>.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit'd model-layout wrappers), ``ref.py`` (pure-jnp oracles).
Validated in interpret mode on CPU; compiled by Mosaic on TPU.
"""
from .bigroots_gates import eval_gates
from .decode_attention import decode_attention
from .flash_attention import flash_attention
from .moe_gmm import grouped_matmul
from .ssd_scan import ssd_intra_chunk

__all__ = [
    "decode_attention",
    "eval_gates",
    "flash_attention",
    "grouped_matmul",
    "ssd_intra_chunk",
]
