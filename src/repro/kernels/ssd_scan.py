"""Pallas TPU kernel: Mamba2 SSD intra-chunk dual form.

The SSD hot spot is the O(Q²) intra-chunk computation (decay-masked
attention-like matmuls) plus the chunk-state contraction — both MXU work.
This kernel computes, per (batch, head, chunk) grid cell, entirely in VMEM:

    y_intra[i]  = Σ_{j≤i} (C_i·B_j) · exp(seg_i − seg_j) · dt_j · x_j
    S_c         = Σ_j B_j ⊗ (x_j · dt_j · exp(seg_last − seg_j))
    seg         = cumsum(dt · A)  (emitted for the outer combine)

The O(n_chunks) inter-chunk state recurrence and the y_inter term stay in
XLA (repro.models.ssd) — they are bandwidth-trivial.  This split is the TPU
adaptation of the paper's GPU kernel: chunk matmuls on the MXU, recurrence
as a short scan instead of a warp-specialized pipeline.

Layouts (pre-transposed by ops.py): x [B, H, Nc, Q, P], dt [B, H, Nc, Q, 1],
B/C [B, H, Nc, Q, N], A [H] → y_intra [B,H,Nc,Q,P], state [B,H,Nc,N,P],
seg [B,H,Nc,Q,1].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(
    a_ref,                        # SMEM [H] f32 (per-head A)
    x_ref, dt_ref, b_ref, c_ref,  # [1,1,1,Q,P], [1,1,1,Q,1], [1,1,1,Q,N] ×2
    y_ref, s_ref, seg_ref,        # [1,1,1,Q,P], [1,1,1,N,P], [1,1,1,Q,1]
    *,
    q_len: int,
):
    h = pl.program_id(1)
    x = x_ref[0, 0, 0].astype(jnp.float32)                  # [Q, P]
    dt = dt_ref[0, 0, 0].astype(jnp.float32)                # [Q, 1]
    B = b_ref[0, 0, 0].astype(jnp.float32)                  # [Q, N]
    C = c_ref[0, 0, 0].astype(jnp.float32)                  # [Q, N]
    A = a_ref[h]

    a = dt * A                                              # [Q, 1] log-decay
    seg = jnp.cumsum(a, axis=0)                             # [Q, 1] inclusive

    # decay(j→i) = exp(seg_i - seg_j) for i ≥ j
    li = seg                                                # [Q, 1] (i)
    lj = seg.reshape(1, q_len)                              # [1, Q] (j)
    decay = jnp.exp(li - lj)                                # [Q, Q]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 1)
    decay = jnp.where(ii >= jj, decay, 0.0)

    scores = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                        # [Q, Q] C_i·B_j
    scores = scores * decay * dt.reshape(1, q_len)           # dt_j weighting
    y = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                        # [Q, P]

    # chunk state: B^T @ (x · dt · decay(j → chunk end))
    state_decay = jnp.exp(seg[q_len - 1] - seg)              # [Q, 1]
    xw = x * (dt * state_decay)                              # [Q, P]
    s = jax.lax.dot_general(
        B, xw, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                        # [N, P]

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)
    s_ref[0, 0, 0] = s
    seg_ref[0, 0, 0] = seg


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(
    x: jax.Array,    # [B, H, Nc, Q, P]
    dt: jax.Array,   # [B, H, Nc, Q]   (post-softplus)
    A: jax.Array,    # [H]             (negative)
    B_: jax.Array,   # [B, H, Nc, Q, N]
    C: jax.Array,    # [B, H, Nc, Q, N]
    *,
    interpret: bool = False,
):
    Bb, H, Nc, Q, P = x.shape
    N = B_.shape[-1]
    dt5 = dt[..., None]
    kernel = functools.partial(_ssd_kernel, q_len=Q)

    def spec(*dims):
        return pl.BlockSpec(
            (1, 1, 1) + dims, lambda b, h, c: (b, h, c, 0, 0)
        )

    from jax.experimental.pallas import tpu as pltpu

    y, s, seg = pl.pallas_call(
        kernel,
        grid=(Bb, H, Nc),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            spec(Q, P), spec(Q, 1), spec(Q, N), spec(Q, N),
        ],
        out_specs=[spec(Q, P), spec(N, P), spec(Q, 1)],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, H, Nc, Q, P), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, Nc, N, P), jnp.float32),
            jax.ShapeDtypeStruct((Bb, H, Nc, Q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(A.astype(jnp.float32), x, dt5, B_, C)
    return y, s, seg[..., 0]
