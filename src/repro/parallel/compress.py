"""Gradient compression: int8 block-quantized collectives + error feedback.

Distributed-optimization trick for DCN-limited multi-pod training: gradients
cross the wire as int8 payloads with per-block f32 scales (≈3.9× fewer
bytes), and the quantization error is fed back into the next step's gradient
(error feedback keeps SGD/Adam convergence — Karimireddy et al., 2019).

``compressed_allreduce_mean`` is shard_map-compatible: each participant
quantizes its local value, all-gathers the int8 payload + scales, and
dequantizes/averages locally, so the HLO collective really moves 1-byte
elements (visible in the dry-run's collective-bytes accounting).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


class Quantized(NamedTuple):
    q: jax.Array       # int8 payload, padded to BLOCK multiple
    scale: jax.Array   # f32 per-block scales
    size: int          # original (unpadded) length


def quantize(x: jax.Array, block: int = BLOCK) -> Quantized:
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return Quantized(q=q, scale=scale[:, 0], size=n)


def dequantize(qt: Quantized, shape, dtype=jnp.float32) -> jax.Array:
    flat = (qt.q.astype(jnp.float32) * qt.scale[:, None]).reshape(-1)[: qt.size]
    return flat.reshape(shape).astype(dtype)


def quantization_error(x: jax.Array) -> jax.Array:
    qt = quantize(x)
    return x.astype(jnp.float32) - dequantize(qt, x.shape)


def compressed_allreduce_mean(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean over ``axis_name`` with int8 wire format (use inside shard_map)."""
    qt = quantize(x)
    qg = jax.lax.all_gather(qt.q, axis_name)          # int8 on the wire
    sg = jax.lax.all_gather(qt.scale, axis_name)      # f32 scales (1/BLOCK size)
    n = qg.shape[0]
    deq = (qg.astype(jnp.float32) * sg[..., None]).reshape(n, -1)[:, : qt.size]
    return deq.mean(axis=0).reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# error feedback across steps
# ---------------------------------------------------------------------------
def ef_init(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def ef_compress(grads: Any, residual: Any) -> tuple[Any, Any]:
    """Returns (quantize-dequantized grads, new residual).  Apply before the
    collective; the residual carries this step's quantization error into the
    next step (error feedback)."""

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        qt = quantize(corrected)
        deq = dequantize(qt, g.shape)
        return deq.astype(g.dtype), corrected - deq

    pairs = jax.tree.map(one, grads, residual)
    deq = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda p: isinstance(p, tuple))
    res = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda p: isinstance(p, tuple))
    return deq, res
