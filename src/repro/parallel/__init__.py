"""Distribution: sharding rules, gradient compression, pipeline parallelism."""
from .compress import (
    compressed_allreduce_mean,
    dequantize,
    ef_compress,
    ef_init,
    quantize,
)
from .sharding import (
    batch_specs,
    cache_shardings,
    cache_spec_for_kv,
    dp_axes,
    dp_size,
    model_size,
    param_shardings,
    param_spec,
)

__all__ = [
    "batch_specs",
    "cache_shardings",
    "cache_spec_for_kv",
    "compressed_allreduce_mean",
    "dequantize",
    "dp_axes",
    "dp_size",
    "ef_compress",
    "ef_init",
    "model_size",
    "param_shardings",
    "param_spec",
    "quantize",
]
