"""Pipeline parallelism: GPipe-style microbatch schedule over a ``pipe`` axis.

Stages are laid out along the mesh's ``pipe`` axis; microbatches flow
stage-to-stage via ``lax.ppermute`` inside ``shard_map``.  The schedule is
the classic (n_micro + n_stages − 1)-tick loop: tick t feeds microbatch t to
stage 0, and stage s processes microbatch (t − s).  Bubble fraction =
(n_stages − 1)/(n_micro + n_stages − 1).

This is an optional axis for deeper-than-memory models; the assigned
production meshes are data×model, so the 40-cell dry-run does not use it —
it is exercised by its own virtual-mesh test (tests/test_parallel.py).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(
    stage_fn: Callable,        # (stage_params, x) -> x
    stage_params,              # pytree stacked on leading n_stages dim
    x: jax.Array,              # [n_micro, mb, ...] microbatched input
    mesh: Mesh,
    axis: str = "pipe",
) -> jax.Array:
    """Run x through n_stages pipeline stages; returns [n_micro, mb, ...]
    outputs (as produced by the last stage)."""
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    assert n_micro >= 1

    param_specs = jax.tree.map(lambda _: P(axis), stage_params)

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(param_specs, P(axis)),   # microbatches start on stage 0? —
        out_specs=P(axis),                 # see gather/scatter note below
        check_rep=False,
    )
    def run(my_params, x_shard):
        # Each stage holds an equal slice of the microbatch dim; gather all
        # microbatches so stage 0 can feed them in order (they are small).
        my_params = jax.tree.map(lambda p: p[0], my_params)
        xs = jax.lax.all_gather(x_shard, axis, tiled=True)     # [n_micro, mb, ...]
        stage = jax.lax.axis_index(axis)
        ticks = n_micro + n_stages - 1
        fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

        buf = jnp.zeros_like(xs[0])                            # stage input
        outs = jnp.zeros_like(xs)                              # last-stage outputs

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any remain)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            feed = jax.lax.dynamic_index_in_dim(xs, mb_idx, 0, keepdims=False)
            inp = jnp.where(stage == 0, feed, buf)
            out = stage_fn(my_params, inp)
            # record finished microbatch (t - n_stages + 1) from the last stage
            done_idx = t - (n_stages - 1)
            write_idx = jnp.clip(done_idx, 0, n_micro - 1)
            is_last = stage == n_stages - 1
            should_write = jnp.logical_and(is_last, done_idx >= 0)
            cur = jax.lax.dynamic_index_in_dim(outs, write_idx, 0, keepdims=False)
            upd = jnp.where(should_write, out, cur)
            outs = jax.lax.dynamic_update_index_in_dim(outs, upd, write_idx, 0)
            # hand off to next stage
            buf = jax.lax.ppermute(out, axis, fwd_perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # broadcast the last stage's results to all stages (all_gather +
        # select — ppermute can't fan out one source), then each stage
        # returns its slice so out_specs P(axis) reassembles the batch.
        outs = jax.lax.all_gather(outs, axis)[n_stages - 1]
        k = n_micro // n_stages
        return jax.lax.dynamic_slice_in_dim(outs, stage * k, k, axis=0)

    return run(stage_params, x)


def stage_split(n_layers: int, n_stages: int) -> list[int]:
    """Even layer split with remainder on early stages."""
    base, rem = divmod(n_layers, n_stages)
    return [base + (1 if i < rem else 0) for i in range(n_stages)]
