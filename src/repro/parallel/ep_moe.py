"""Explicit expert parallelism: shard_map MoE with all_to_all dispatch.

GSPMD cannot partition the token-sorted ragged MoE (the global argsort +
ragged GEMM force full replication — measured 100× worse than dense in §Perf
hc2 iteration 1).  This module is the production answer: experts live on the
``model`` axis, tokens are sequence-sharded into the block, and dispatch is
the GShard/Switch capacity-based all_to_all:

  1. route locally (router is replicated, top-k per token),
  2. pack per-destination send buffers [M, C, d] (capacity C, overflow
     tokens dropped — weights renormalized over surviving experts),
  3. all_to_all over the model axis,
  4. local expert FFN via ragged GEMM over the device's E/M experts,
  5. all_to_all back + weighted scatter-add into the token stream.

Per-device a2a payload = T_loc·k·cf·d ≪ the dense formulation's [T, E, f]
intermediates; per-device FLOPs = active-expert FLOPs only.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

# The enclosing launcher publishes the concrete mesh here before tracing
# (shard_map needs it; model code only knows axis names).
_MESH = None


def set_mesh(mesh) -> None:
    global _MESH
    _MESH = mesh


def get_mesh():
    if _MESH is None:
        raise RuntimeError("ep_moe.set_mesh(mesh) must be called before tracing")
    return _MESH


def _dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def ep_moe_apply(p, x, cfg, capacity_factor: float = 1.25):
    """x: [B, S, d] (batch sharded over dp, replicated over model outside).
    Returns (y, aux) like moe_apply.  Must be traced under the mesh."""
    mesh = get_mesh()
    M = mesh.shape["model"]
    dp = _dp_axes(mesh)
    E = cfg.moe_experts
    assert E % M == 0, (E, M)
    e_local = E // M
    k = cfg.moe_top_k
    B, S, d = x.shape
    assert S % M == 0, (S, M)

    w_specs = jax.tree.map(lambda _: P(), p)
    w_specs = dict(w_specs)
    for name in ("w_gate", "w_up", "w_down"):
        w_specs[name] = P("model", None, None)
    w_specs["router"] = P(None, None)
    w_specs["norm_scale"] = P(None)

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(w_specs, P(dp, "model", None)),
        out_specs=(P(dp, "model", None), P()),
        check_rep=False,
    )
    def block(pw, x_blk):
        b_loc, s_loc, _ = x_blk.shape
        t_loc = b_loc * s_loc
        xt = x_blk.reshape(t_loc, d)
        my = jax.lax.axis_index("model")

        # 1. local routing
        logits = (xt @ pw["router"].astype(xt.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        weights, experts = jax.lax.top_k(probs, k)                # [t, k]
        weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

        # aux (local shard statistics; psum over all axes for global view)
        onehot = jax.nn.one_hot(experts, E, dtype=jnp.float32)
        load = onehot.sum(axis=(0, 1))
        load = jax.lax.psum(load, ("model",) + dp)
        load = load / jnp.maximum(load.sum(), 1.0)
        importance = jax.lax.pmean(probs.mean(axis=0), ("model",) + dp)
        lb = E * jnp.sum(load * importance)
        z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
        z = jax.lax.pmean(z, ("model",) + dp)

        # 2. pack per-destination send buffers with capacity
        cap = int((t_loc * k) / M * capacity_factor + 0.999)
        flat_exp = experts.reshape(-1)                            # [t*k]
        flat_w = weights.reshape(-1)
        token_of = jnp.repeat(jnp.arange(t_loc), k)
        dest = flat_exp // e_local
        order = jnp.argsort(dest)                                 # group by dest
        dest_s, exp_s = dest[order], flat_exp[order]
        tok_s, w_s = token_of[order], flat_w[order]
        # position within destination group
        pos = jnp.arange(t_loc * k) - jnp.searchsorted(
            dest_s, dest_s, side="left"
        )
        keep = pos < cap
        slot = dest_s * cap + jnp.where(keep, pos, 0)

        send_x = jnp.zeros((M * cap, d), x_blk.dtype)
        send_x = send_x.at[slot].set(
            jnp.where(keep[:, None], xt[tok_s], 0), mode="drop"
        )
        send_exp = jnp.full((M * cap,), 0, jnp.int32)
        send_exp = send_exp.at[slot].set(
            jnp.where(keep, (exp_s % e_local).astype(jnp.int32), 0), mode="drop"
        )
        send_valid = jnp.zeros((M * cap,), jnp.bool_)
        send_valid = send_valid.at[slot].set(keep, mode="drop")

        # 3. dispatch
        recv_x = jax.lax.all_to_all(
            send_x.reshape(M, cap, d), "model", 0, 0, tiled=True
        ).reshape(M * cap, d)
        recv_exp = jax.lax.all_to_all(
            send_exp.reshape(M, cap), "model", 0, 0, tiled=True
        ).reshape(M * cap)
        recv_valid = jax.lax.all_to_all(
            send_valid.reshape(M, cap), "model", 0, 0, tiled=True
        ).reshape(M * cap)

        # 4. local expert FFN (ragged GEMM over my e_local experts)
        eid = jnp.where(recv_valid, recv_exp, e_local - 1)
        r_order = jnp.argsort(eid)
        xr = jnp.where(recv_valid[r_order, None], recv_x[r_order], 0)
        group_sizes = jnp.bincount(eid, length=e_local)
        cdt = x_blk.dtype
        g = jax.lax.ragged_dot(xr, pw["w_gate"].astype(cdt), group_sizes)
        u = jax.lax.ragged_dot(xr, pw["w_up"].astype(cdt), group_sizes)
        h = jax.nn.silu(g) * u
        yr = jax.lax.ragged_dot(h, pw["w_down"].astype(cdt), group_sizes)
        y_back = jnp.zeros_like(yr).at[r_order].set(yr)

        # 5. return + weighted combine at the source
        ret = jax.lax.all_to_all(
            y_back.reshape(M, cap, d), "model", 0, 0, tiled=True
        ).reshape(M * cap, d)
        contrib = jnp.where(keep[:, None], ret[slot], 0.0)
        y = jnp.zeros((t_loc, d), cdt)
        y = y.at[tok_s].add(contrib * w_s[:, None].astype(cdt))

        from ..models.moe import MoeAux

        return y.reshape(b_loc, s_loc, d), MoeAux(lb, z, load)

    return block(p, x)
