"""Sharding rules: parameter-path → PartitionSpec for the production meshes.

Axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  Batch shards over pod×data; attention heads / FFN hidden /
experts / vocab shard over model (tensor/expert parallelism); KV projections
replicate when ``n_kv_heads`` doesn't divide the model axis (glm4 kv=2,
granite kv=8 on a 16-way axis) — the grouped-replication standard.

Decode caches pick one of three layouts (DESIGN.md §5):
  - head-sharded   [nb, B@dp, S, KV@model, hd]   when KV divides model
  - seq-sharded    [nb, B@dp, S@model, KV, hd]   when it doesn't
  - fully-seq      [nb, B, S@(dp+model), KV, hd] when batch < dp size
    (long_500k, batch=1: the whole mesh splits the sequence)
"""
from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# mesh helpers
# ---------------------------------------------------------------------------
def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, *axes: str) -> int:
    return int(np.prod([mesh.shape[a] for a in axes if a in mesh.axis_names]))


def dp_size(mesh: Mesh) -> int:
    return axis_size(mesh, *dp_axes(mesh))


def model_size(mesh: Mesh) -> int:
    return axis_size(mesh, "model")


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------
def _path_names(path) -> list[str]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "name"):
            names.append(str(k.name))
        else:
            names.append(str(k))
    return names


def param_spec(path_names: list[str], ndim: int, cfg, mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf (rules above)."""
    name = path_names[-1]
    kv_ok = (
        cfg.n_kv_heads > 0 and cfg.n_kv_heads % model_size(mesh) == 0
    )

    def last_dims(*spec):
        """Pad with None on the left for stacked (block) leading dims."""
        return P(*([None] * (ndim - len(spec)) + list(spec)))

    if name == "embed":
        return P("model", None)
    if name == "head":
        return P(None, "model")
    if "norm" in name:                      # all norm vectors except inner
        if name == "inner_norm":
            return last_dims("model")
        return last_dims(None)
    if name in ("wq", "bq"):
        return last_dims(None, "model") if name == "wq" else last_dims("model")
    if name in ("wk", "wv"):
        return last_dims(None, "model") if kv_ok else last_dims(None, None)
    if name in ("bk", "bv"):
        return last_dims("model") if kv_ok else last_dims(None)
    if name == "wo":
        return last_dims("model", None)
    if name in ("w_gate", "w_up"):
        if ndim >= 4:                       # MoE stacked experts [nb,E,d,f]
            return last_dims("model", None, None)
        return last_dims(None, "model")
    if name == "w_down":
        if ndim >= 4:
            return last_dims("model", None, None)
        return last_dims("model", None)
    if name == "router":
        return last_dims(None, None)
    if name in ("wz", "wx"):
        return last_dims(None, "model")
    if name in ("wbc", "wdt"):
        return last_dims(None, None)
    if name == "conv_x_w":
        return last_dims(None, "model")
    if name == "conv_x_b":
        return last_dims("model")
    if name in ("conv_bc_w", "conv_bc_b", "A_log", "D", "dt_bias"):
        return last_dims(*([None] * min(ndim, 1)))
    if name == "out_proj":
        return last_dims("model", None)
    return P()  # replicate anything unmatched (scalars, counters)


def param_shardings(abstract_params: Any, cfg, mesh: Mesh):
    """NamedSharding pytree matching an abstract (or concrete) param tree."""

    def assign(path, leaf):
        spec = param_spec(_path_names(path), len(leaf.shape), cfg, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(assign, abstract_params)


# ---------------------------------------------------------------------------
# batch / activation / cache rules
# ---------------------------------------------------------------------------
def batch_specs(cfg, mesh: Mesh, batch_size: int, *, has_embeds: bool = False,
                encdec: bool = False) -> dict[str, P]:
    dp = dp_axes(mesh)
    shardable = batch_size % dp_size(mesh) == 0
    bspec = P(dp) if shardable else P()
    specs = {
        "tokens": P(*bspec, None),
        "labels": P(*bspec, None),
    }
    if has_embeds:
        specs["embeds"] = P(*bspec, None, None)
    if encdec:
        specs["enc_embeds"] = P(*bspec, None, None)
    return specs


def cache_spec_for_kv(cfg, mesh: Mesh, batch_size: int) -> P:
    """Spec for [nb, B, S, KV, hd] attention caches (layout table above).

    §Perf hc3 iteration 3: sharding the cache *sequence* dim makes the
    per-step dynamic_update_slice un-partitionable (GSPMD falls back to
    "involuntary full rematerialization" — it replicates the whole cache).
    When KV heads don't divide the model axis we shard ``head_dim`` instead:
    the QK contraction becomes a sharded reduction (tiny logits psum) and
    cache writes stay local.  Sequence stays sharded over dp when the batch
    can't be (long_500k, batch=1)."""
    dp = dp_axes(mesh)
    kv_ok = cfg.n_kv_heads % model_size(mesh) == 0
    hd_ok = cfg.head_dim % model_size(mesh) == 0
    batch_ok = batch_size % dp_size(mesh) == 0
    if batch_ok and kv_ok:
        return P(None, dp, None, "model", None)
    if batch_ok:
        return P(None, dp, None, None, "model" if hd_ok else None)
    return P(None, None, dp, None, "model" if hd_ok else None)


def cache_shardings(cfg, mesh: Mesh, abstract_cache: Any, batch_size: int):
    """Shardings for an lm.init_cache pytree (attention + ssm slots)."""
    dp = dp_axes(mesh)
    batch_ok = batch_size % dp_size(mesh) == 0
    bax = dp if batch_ok else None
    kv_spec = cache_spec_for_kv(cfg, mesh, batch_size)
    h_ok = cfg.ssm_state and cfg.ssm_heads % model_size(mesh) == 0
    di_ok = cfg.ssm_state and cfg.d_inner % model_size(mesh) == 0

    def assign(path, leaf):
        name = _path_names(path)[-1]
        if name in ("k", "v"):
            spec = kv_spec
        elif name == "conv_x":
            spec = P(None, bax, None, "model" if di_ok else None)
        elif name == "conv_bc":
            spec = P(None, bax, None, None)
        elif name == "ssm":
            spec = P(None, bax, "model" if h_ok else None, None, None)
        elif name == "len":
            spec = P()
        else:
            spec = P()
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(assign, abstract_cache)


def logits_spec(cfg, mesh: Mesh, batch_size: int) -> P:
    dp = dp_axes(mesh)
    shardable = batch_size % dp_size(mesh) == 0
    return P(dp if shardable else None, None, "model")
